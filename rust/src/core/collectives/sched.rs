//! The collective **schedule engine**.
//!
//! Every collective is expressed as a per-rank *schedule*: an ordered
//! list of send / receive / local-reduce steps over the communicator's
//! collective context plane, advanced incrementally by the progress
//! engine ([`crate::core::request::progress`]). A nonblocking collective
//! (`MPI_Ibcast`, `MPI_Iallreduce`, …) is a request whose kind holds its
//! schedule; the blocking collectives are `wait(i<coll>())` over the same
//! schedules, so there is exactly one implementation of each algorithm.
//!
//! This is the schedule/progress design MPICH uses for its nonblocking
//! collectives (Zhou et al., "Designing and Prototyping Extensions to
//! MPI in MPICH"), shrunk to this engine's eager transport:
//!
//! * sends are eager — executing a send step enqueues an envelope and
//!   never blocks;
//! * a receive step *parks* the schedule until a matching envelope shows
//!   up in the unexpected queue, then applies its [`RecvAction`];
//! * tag phases (`base_tag + phase`, see [`super::PHASES_PER_COLL`])
//!   separate the rounds of one collective, while the per-comm collective
//!   sequence separates *concurrent* collectives — which is what makes
//!   out-of-order completion of overlapping nonblocking collectives safe.
//!
//! # Restartability (persistent collectives, MPI-4)
//!
//! A schedule is a **reusable program**: an immutable step list executed
//! from a program counter, plus a small list of start-time [`Prep`]
//! actions that refresh the data the steps carry (re-packing user
//! buffers, so each start observes their current contents). The
//! nonblocking entry points arm-and-run a schedule once; the `*_init`
//! entry points park the same schedule inside an Inactive persistent
//! request, and each `MPI_Start` re-arms it via [`start_sched`] —
//! **reset and re-run, never rebuild**. [`schedules_built`] counts
//! constructions so benches and tests can prove the reuse.
//!
//! Tag discipline across restarts: a persistent collective keeps the
//! base tag allocated at `*_init` time (the init calls are collective,
//! so all ranks agree). Consecutive starts of the same request reuse
//! that tag safely because messages between one (src, context, tag)
//! pair are delivered and matched in FIFO order; other collectives on
//! the comm advance the per-comm sequence and stay on different tags —
//! until the 24-bit sequence wraps (~16.7M collectives on one comm
//! while the persistent request stays alive), the same transient
//! wrap-collision window the nonblocking family already has.
//!
//! Schedules progress whenever the rank enters the progress engine
//! (any test/wait/recv), so an `iallreduce` overlaps pt2pt traffic and
//! other collectives on the same communicator.

use super::{children_of, coll_begin, parent_of, CollCtx};
use crate::core::comm::comm_size;
use crate::core::datatype::pack::{pack, unpack};
use crate::core::request::{
    new_persistent, new_request, enqueue_send, PersistSpec, ReqKind, ReqState, StatusCore,
};
use crate::core::transport::{Envelope, MsgKind, Payload};
use crate::core::world::{with_ctx, RankCtx};
use crate::core::{err, CommId, DtId, OpId, RC, ReqId};

/// Count of schedule constructions in the **calling rank's job** (the
/// counter lives on the [`World`], so parallel jobs in one process —
/// e.g. concurrently running tests — never perturb each other). A
/// persistent collective builds exactly one schedule per rank at
/// `*_init`; each nonblocking call builds one. Benches and tests read
/// the delta across a start/wait loop to prove that persistent
/// collectives reuse, not rebuild. Returns 0 on an unbound thread.
///
/// [`World`]: crate::core::world::World
pub fn schedules_built() -> u64 {
    crate::core::world::try_ctx(|ctx| ctx.map(|c| c.world.sched_builds()).unwrap_or(0))
}

// ---------------------------------------------------------------------------
// Schedule representation
// ---------------------------------------------------------------------------

/// What to do with the bytes of a matched receive step.
#[derive(Clone, Copy)]
pub(crate) enum RecvAction {
    /// Drop the payload (pure synchronization, e.g. barrier rounds).
    Discard,
    /// Replace the accumulator with the payload (tree broadcast).
    Store,
    /// Copy the payload into the accumulator at `offset` (gather phases).
    StoreAt { offset: usize, len: usize },
    /// Stash the payload in the auxiliary buffer (exscan's partial).
    StoreAux,
    /// Fold the payload into the accumulator: `accum = op(payload, accum)`
    /// (reduction trees and scan chains; fold order matches the blocking
    /// algorithms so non-commutative user ops see identical bracketing).
    Combine { op: OpId, count: usize, dt: DtId },
    /// Fold the payload into `accum[offset..offset+len]` only — the
    /// segmented reductions of the ring and Rabenseifner allreduce
    /// variants (`count` = elements in the segment).
    CombineAt { op: OpId, offset: usize, len: usize, count: usize, dt: DtId },
    /// Scatter the payload back into the accumulator ranges listed in
    /// `Schedule::bands[band]`, in order (Bruck rounds). Indexing the
    /// side table keeps this enum `Copy`.
    ScatterBands { band: usize },
    /// Unpack the payload straight into user memory at `buf + displ`
    /// (rooted gathers, scatter leaves, alltoall blocks).
    Unpack { buf: usize, displ: isize, count: usize, dt: DtId },
}

/// One step of a per-rank collective schedule. Peers are *comm ranks*;
/// `phase` offsets the collective's base tag (bounded by
/// [`super::PHASES_PER_COLL`]). Steps are immutable during execution —
/// the program counter walks them, and only [`Prep`] actions (run at
/// arm time) refresh the data they carry.
pub(crate) enum Step {
    /// Eager-send a byte block. The block is filled at arm time by a
    /// [`Prep::PackStep`] action (or stays empty: barrier rounds).
    Send { to: usize, phase: i32, data: Vec<u8> },
    /// Eager-send the accumulator (or `range` of it) *as of execution
    /// time* — for data produced by earlier receive steps.
    SendAccum { to: usize, phase: i32, range: Option<(usize, usize)> },
    /// Eager-send the concatenation of the accumulator ranges listed in
    /// `Schedule::bands[band]` *as of execution time* (the non-contiguous
    /// block sets a Bruck round ships in one envelope).
    SendAccumBands { to: usize, phase: i32, band: usize },
    /// Park until a message from `from` on `phase` arrives, then apply
    /// `action`.
    Recv { from: usize, phase: i32, action: RecvAction },
    /// `accum = op(aux, accum)` (exscan's forward combine).
    FoldAux { op: OpId, count: usize, dt: DtId },
    /// Unpack accumulator bytes (or `range` of them; or the aux buffer)
    /// into user memory at `buf + displ`.
    Unpack {
        buf: usize,
        displ: isize,
        count: usize,
        dt: DtId,
        range: Option<(usize, usize)>,
        from_aux: bool,
    },
}

/// A start-time data-refresh action. Preps re-read the *user buffers*
/// captured at build time, so every start of a persistent collective
/// observes their current contents (MPI-4 semantics); the one-shot
/// nonblocking path runs them exactly once, at submit.
#[derive(Clone, Copy)]
pub(crate) enum Prep {
    /// `accum = pack(count items of dt at buf + displ)`.
    PackAccum { buf: usize, displ: isize, count: usize, dt: DtId },
    /// `accum = [0u8; len]` (gather staging area).
    ClearAccum { len: usize },
    /// Overwrite `accum[off..]` with packed user bytes (a root's own
    /// block in the gather staging area). Runs after [`Prep::ClearAccum`].
    PackAccumAt { off: usize, buf: usize, displ: isize, count: usize, dt: DtId },
    /// Fill `program[idx]` (a [`Step::Send`]) with packed user bytes.
    PackStep { idx: usize, buf: usize, displ: isize, count: usize, dt: DtId },
    /// Local self-exchange: pack from one user buffer, unpack into
    /// another (root's own block in gather/scatter, alltoall diagonal).
    Exchange {
        sbuf: usize,
        sdispl: isize,
        scount: usize,
        sdt: DtId,
        dbuf: usize,
        ddispl: isize,
        dcount: usize,
        ddt: DtId,
    },
}

/// A per-rank collective schedule: the restartable program of one
/// collective. Lives inside its request ([`ReqKind::Sched`]) and is
/// advanced by [`progress_scheds`]; persistent requests retain it across
/// starts and [`start_sched`] re-arms it in place.
pub struct Schedule {
    /// Member world ranks, comm-rank order (snapshot from coll_begin).
    members: Vec<usize>,
    /// The collective context id of the communicator.
    context: u32,
    /// Base tag of this collective (phases offset it). Persistent
    /// schedules keep it across starts — see the module docs.
    tag: i32,
    /// Start-time data refresh, run by [`arm`] before each execution.
    prep: Vec<Prep>,
    /// The step program, executed from [`Schedule::pc`] forward.
    program: Vec<Step>,
    /// Program counter: next step to execute.
    pc: usize,
    /// Working buffer (packed bytes) threaded through the steps.
    accum: Vec<u8>,
    /// Secondary buffer for algorithms needing two live values (exscan).
    aux: Vec<u8>,
    /// Payload bytes received so far (reported in the final status).
    recv_bytes: u64,
    /// Staging buffer for arm-time preps (self-exchange, gather own
    /// block) — retained so restarts stay allocation-free.
    scratch: Vec<u8>,
    /// Whether this schedule will be re-armed ([`submit_init`] sets it).
    /// One-shot schedules surrender their send blocks instead of copying.
    persistent: bool,
    /// Accumulator range lists referenced by [`Step::SendAccumBands`] and
    /// [`RecvAction::ScatterBands`] — immutable after build, so restarts
    /// reuse them.
    bands: Vec<Vec<(usize, usize)>>,
    /// Algorithm id of this schedule ([`crate::core::obs`]'s
    /// `COLL_ALGO_*`; 0 = unlabeled). Stamped into the high byte of the
    /// CollStep trace word.
    algo: u8,
}

impl Schedule {
    fn new(cc: CollCtx) -> Schedule {
        crate::core::world::try_ctx(|ctx| {
            if let Some(c) = ctx {
                c.world.note_sched_build();
            }
        });
        Schedule {
            members: cc.members,
            context: cc.context,
            tag: cc.tag,
            prep: Vec::new(),
            program: Vec::new(),
            pc: 0,
            accum: Vec::new(),
            aux: Vec::new(),
            recv_bytes: 0,
            scratch: Vec::new(),
            persistent: false,
            bands: Vec::new(),
            algo: 0,
        }
    }

    fn push(&mut self, s: Step) {
        self.program.push(s);
    }

    /// Index the next step will get (for [`Prep::PackStep`] targets).
    fn next_idx(&self) -> usize {
        self.program.len()
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

/// Clamped view of `buf[off..off+len]`. Ranges are derived from counts
/// the *local* rank passed; if a peer disagrees (a user error MPI reports
/// as truncation), the mismatch must not become a cross-thread panic.
fn ranged(buf: &[u8], range: Option<(usize, usize)>) -> &[u8] {
    match range {
        Some((off, len)) => {
            let start = off.min(buf.len());
            let end = off.saturating_add(len).min(buf.len());
            &buf[start..end]
        }
        None => buf,
    }
}

fn send_payload(ctx: &RankCtx, s: &Schedule, to: usize, phase: i32, payload: Payload) {
    let env = Envelope {
        src: ctx.rank as u32,
        context: s.context,
        tag: s.tag + phase,
        kind: MsgKind::Eager,
        seq: 0,
        payload,
    };
    enqueue_send(ctx, s.members[to], env);
}

fn apply_recv(ctx: &RankCtx, s: &mut Schedule, payload: Payload, action: RecvAction) -> RC<()> {
    let data = payload.as_slice();
    match action {
        RecvAction::Discard => Ok(()),
        RecvAction::Store => {
            s.accum.clear();
            s.accum.extend_from_slice(data);
            Ok(())
        }
        RecvAction::StoreAt { offset, len } => {
            let end = (offset + len).min(s.accum.len());
            if offset < end {
                let take = (end - offset).min(data.len());
                s.accum[offset..offset + take].copy_from_slice(&data[..take]);
            }
            Ok(())
        }
        RecvAction::StoreAux => {
            s.aux.clear();
            s.aux.extend_from_slice(data);
            Ok(())
        }
        RecvAction::Combine { op, count, dt } => {
            crate::core::op::apply(op, data, &mut s.accum, count, dt)
        }
        RecvAction::CombineAt { op, offset, len, count, dt } => {
            let end = offset.saturating_add(len).min(s.accum.len());
            if offset >= end {
                return Ok(());
            }
            let take = (end - offset).min(data.len());
            crate::core::op::apply(op, &data[..take], &mut s.accum[offset..offset + take], count, dt)
        }
        RecvAction::ScatterBands { band } => {
            let mut pos = 0usize;
            for &(off, len) in &s.bands[band] {
                let end = off.saturating_add(len).min(s.accum.len());
                if off < end {
                    let take = (end - off).min(data.len().saturating_sub(pos));
                    if take > 0 {
                        s.accum[off..off + take].copy_from_slice(&data[pos..pos + take]);
                    }
                }
                pos += len;
            }
            Ok(())
        }
        RecvAction::Unpack { buf, displ, count, dt } => {
            let t = ctx.tables.borrow();
            let dst = unsafe { (buf as *mut u8).offset(displ) };
            unpack(&t.dtypes, data, dst, count, dt)?;
            Ok(())
        }
    }
}

/// Run the start-time prep actions and reset the program counter —
/// everything [`start_sched`] (and the one-shot submit path) needs to
/// (re)launch a schedule. User buffers are re-read here, so restarts
/// pick up updated contents; heap allocations (accum, step data blocks)
/// are reused across starts.
fn arm(ctx: &RankCtx, s: &mut Schedule) -> RC<()> {
    s.pc = 0;
    s.recv_bytes = 0;
    s.aux.clear();
    for i in 0..s.prep.len() {
        match s.prep[i] {
            Prep::PackAccum { buf, displ, count, dt } => {
                s.accum.clear();
                let t = ctx.tables.borrow();
                let src = unsafe { (buf as *const u8).offset(displ) };
                pack(&t.dtypes, src, count, dt, &mut s.accum)?;
            }
            Prep::ClearAccum { len } => {
                s.accum.clear();
                s.accum.resize(len, 0);
            }
            Prep::PackAccumAt { off, buf, displ, count, dt } => {
                s.scratch.clear();
                {
                    let t = ctx.tables.borrow();
                    let src = unsafe { (buf as *const u8).offset(displ) };
                    pack(&t.dtypes, src, count, dt, &mut s.scratch)?;
                }
                if off < s.accum.len() {
                    let take = s.scratch.len().min(s.accum.len() - off);
                    s.accum[off..off + take].copy_from_slice(&s.scratch[..take]);
                }
            }
            Prep::PackStep { idx, buf, displ, count, dt } => {
                let t = ctx.tables.borrow();
                let src = unsafe { (buf as *const u8).offset(displ) };
                if let Some(Step::Send { data, .. }) = s.program.get_mut(idx) {
                    data.clear();
                    pack(&t.dtypes, src, count, dt, data)?;
                }
            }
            Prep::Exchange { sbuf, sdispl, scount, sdt, dbuf, ddispl, dcount, ddt } => {
                s.scratch.clear();
                let t = ctx.tables.borrow();
                let src = unsafe { (sbuf as *const u8).offset(sdispl) };
                pack(&t.dtypes, src, scount, sdt, &mut s.scratch)?;
                let dst = unsafe { (dbuf as *mut u8).offset(ddispl) };
                unpack(&t.dtypes, &s.scratch, dst, dcount, ddt)?;
            }
        }
    }
    Ok(())
}

/// Run `s` as far as it will go without blocking. `Ok(true)` = finished.
fn advance(ctx: &RankCtx, s: &mut Schedule) -> RC<bool> {
    let persistent = s.persistent;
    while s.pc < s.program.len() {
        match &mut s.program[s.pc] {
            Step::Send { to, phase, data } => {
                let (to, phase) = (*to, *phase);
                let payload = if persistent {
                    // Re-armed schedules keep the block (Prep::PackStep
                    // refills it at the next start).
                    Payload::from_slice(data)
                } else {
                    // One-shot: move the built block, no copy.
                    Payload::from_vec(std::mem::take(data))
                };
                send_payload(ctx, s, to, phase, payload);
            }
            Step::SendAccum { to, phase, range } => {
                let (to, phase, range) = (*to, *phase, *range);
                let payload = Payload::from_slice(ranged(&s.accum, range));
                send_payload(ctx, s, to, phase, payload);
            }
            Step::SendAccumBands { to, phase, band } => {
                let (to, phase, band) = (*to, *phase, *band);
                let mut data = Vec::new();
                for &(off, len) in &s.bands[band] {
                    data.extend_from_slice(ranged(&s.accum, Some((off, len))));
                }
                send_payload(ctx, s, to, phase, Payload::from_vec(data));
            }
            Step::Recv { from, phase, action } => {
                let (from, phase, action) = (*from, *phase, *action);
                let want_src = s.members[from] as i32;
                let tag = s.tag + phase;
                let matched =
                    ctx.state.borrow_mut().match_index.take_unexpected(s.context, want_src, tag);
                match matched {
                    Some(env) => {
                        s.recv_bytes += env.payload.len() as u64;
                        apply_recv(ctx, s, env.payload, action)?;
                    }
                    None => {
                        // ULFM: a parked step waiting on a dead peer (or
                        // a revoked comm) can never unpark — abort the
                        // schedule; the error lands in the request status
                        // and surfaces at wait/test. Checked only on a
                        // miss, so data the peer sent before dying still
                        // flows through the schedule.
                        if ctx.world.is_revoked(s.context) {
                            return Err(err!(MPI_ERR_REVOKED));
                        }
                        if ctx.world.is_dead(s.members[from]) {
                            ctx.obs.note_op_failed_proc();
                            return Err(err!(MPI_ERR_PROC_FAILED));
                        }
                        // Not here yet: park on this step (pc unchanged).
                        return Ok(false);
                    }
                }
            }
            Step::FoldAux { op, count, dt } => {
                let (op, count, dt) = (*op, *count, *dt);
                let aux = std::mem::take(&mut s.aux);
                let r = crate::core::op::apply(op, &aux, &mut s.accum, count, dt);
                s.aux = aux;
                r?;
            }
            Step::Unpack { buf, displ, count, dt, range, from_aux } => {
                let (buf, displ, count, dt, range, from_aux) =
                    (*buf, *displ, *count, *dt, *range, *from_aux);
                let src = ranged(if from_aux { &s.aux } else { &s.accum }, range);
                let t = ctx.tables.borrow();
                let dst = unsafe { (buf as *mut u8).offset(displ) };
                unpack(&t.dtypes, src, dst, count, dt)?;
            }
        }
        crate::core::obs::trace(
            ctx,
            crate::core::obs::TraceKind::CollStep,
            s.context,
            ((s.algo as u32) << 24) | (s.pc as u32 & 0x00FF_FFFF),
        );
        s.pc += 1;
    }
    Ok(true)
}

fn complete_status(s: &Schedule) -> StatusCore {
    let mut st = StatusCore::empty();
    st.count_bytes = s.recv_bytes;
    st
}

/// Register a built schedule as a one-shot (nonblocking) request,
/// arming and advancing it once immediately (local-only schedules —
/// size-1 comms, leaf-only work — complete here).
fn submit(ctx: &RankCtx, mut s: Schedule) -> RC<ReqId> {
    arm(ctx, &mut s)?;
    if advance(ctx, &mut s)? {
        return Ok(new_request(ctx, ReqKind::Send, ReqState::Complete(complete_status(&s))));
    }
    let rid = new_request(ctx, ReqKind::Sched(Box::new(s)), ReqState::Active);
    ctx.state.borrow_mut().active_scheds.push(rid);
    Ok(rid)
}

/// Park a built schedule inside an **Inactive persistent** request
/// (`MPI_Bcast_init` & co.). Nothing runs until `MPI_Start`.
fn submit_init(ctx: &RankCtx, mut s: Schedule) -> RC<ReqId> {
    s.persistent = true;
    Ok(new_persistent(ctx, ReqKind::Sched(Box::new(s)), PersistSpec::Coll))
}

/// `MPI_Start` for a persistent collective: re-arm the retained schedule
/// (reset program counter, re-run preps) and advance it once. Called
/// from the engine's start path; the request is known Inactive.
pub(crate) fn start_sched(ctx: &RankCtx, rid: ReqId) -> RC<()> {
    // Move the schedule out of the request table so arming/advancing can
    // re-borrow tables (pack/unpack, user ops) freely.
    let mut sched = {
        let mut t = ctx.tables.borrow_mut();
        let req = t.reqs.get_mut(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
        match std::mem::replace(&mut req.kind, ReqKind::Send) {
            ReqKind::Sched(s) => s,
            other => {
                req.kind = other;
                return Err(err!(MPI_ERR_REQUEST));
            }
        }
    };
    // A successful extraction is a schedule *reuse* — the build cost was
    // paid once at `*_init`; this is the re-arm the pvar counts.
    ctx.world.obs.note_sched_reuse();
    let outcome = arm(ctx, &mut sched).and_then(|()| advance(ctx, &mut sched));
    let became_active = {
        let mut t = ctx.tables.borrow_mut();
        let req = t.reqs.get_mut(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
        let active = match &outcome {
            Ok(true) => {
                req.state = ReqState::Complete(complete_status(&sched));
                false
            }
            Ok(false) => {
                req.state = ReqState::Active;
                true
            }
            Err(e) => {
                // Errors land in the status (surfaced at wait/test); the
                // schedule survives, so a restart after error is legal.
                let mut st = complete_status(&sched);
                st.error = e.class;
                req.state = ReqState::Complete(st);
                false
            }
        };
        req.kind = ReqKind::Sched(sched);
        active
    };
    if became_active {
        ctx.state.borrow_mut().active_scheds.push(rid);
    }
    Ok(())
}

/// Progress-engine hook: advance every in-flight schedule. Called from
/// [`crate::core::request::progress`] after the fabric drain, so parked
/// receive steps see freshly-arrived envelopes.
///
/// Allocation-free: this sits inside every wait/test spin loop, so it
/// walks `active_scheds` in place (`swap_remove` on completion) instead
/// of snapshotting it.
pub(crate) fn progress_scheds(ctx: &RankCtx) {
    // Re-entrancy guard: a user reduction op may legally call back into
    // MPI (and thus into progress) while a Combine step runs.
    if ctx.sched_pump.get() {
        return;
    }
    if ctx.state.borrow().active_scheds.is_empty() {
        return;
    }
    ctx.sched_pump.set(true);
    enum Taken {
        Sched(Box<Schedule>),
        Keep,
        Drop,
    }
    let mut i = 0usize;
    loop {
        // Re-read the list each step: a user op callback may submit new
        // collectives (appends) while we pump.
        let Some(rid) = ctx.state.borrow().active_scheds.get(i).copied() else { break };
        // Move the schedule out of the request table so advancing it can
        // re-borrow tables (pack/unpack, user ops) freely.
        let taken = {
            let mut t = ctx.tables.borrow_mut();
            match t.reqs.get_mut(rid.0) {
                Some(req) if req.state == ReqState::Active => {
                    match std::mem::replace(&mut req.kind, ReqKind::Send) {
                        ReqKind::Sched(s) => Taken::Sched(s),
                        other => {
                            req.kind = other;
                            Taken::Keep
                        }
                    }
                }
                // Completed and/or already freed by the user.
                _ => Taken::Drop,
            }
        };
        let keep = match taken {
            Taken::Keep => true,
            Taken::Drop => false,
            Taken::Sched(mut sched) => {
                let outcome = advance(ctx, &mut sched);
                let mut t = ctx.tables.borrow_mut();
                match t.reqs.get_mut(rid.0) {
                    None => false,
                    Some(req) => match outcome {
                        Ok(true) => {
                            req.state = ReqState::Complete(complete_status(&sched));
                            if req.persist.is_some() {
                                // Persistent collective: the schedule
                                // survives for the next MPI_Start.
                                req.kind = ReqKind::Sched(sched);
                            }
                            false
                        }
                        Ok(false) => {
                            req.kind = ReqKind::Sched(sched);
                            true
                        }
                        Err(e) => {
                            let mut st = complete_status(&sched);
                            st.error = e.class;
                            req.state = ReqState::Complete(st);
                            if req.persist.is_some() {
                                req.kind = ReqKind::Sched(sched);
                            }
                            false
                        }
                    },
                }
            }
        };
        if keep {
            i += 1;
        } else {
            // The swapped-in tail element is unprocessed; revisit index i.
            ctx.state.borrow_mut().active_scheds.swap_remove(i);
        }
    }
    ctx.sched_pump.set(false);
}

// ---------------------------------------------------------------------------
// Build helpers
// ---------------------------------------------------------------------------

fn in_place(p: *const u8) -> bool {
    p as usize == crate::abi::constants::MPI_IN_PLACE
}

fn packed_len(ctx: &RankCtx, count: usize, dt: DtId) -> RC<usize> {
    let t = ctx.tables.borrow();
    Ok(t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?.size * count)
}

fn extent_of(ctx: &RankCtx, dt: DtId) -> RC<isize> {
    let t = ctx.tables.borrow();
    Ok(t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?.extent)
}

fn check_root(cc: &CollCtx, root: i32) -> RC<usize> {
    if root < 0 || root as usize >= cc.size() {
        return Err(err!(MPI_ERR_ROOT));
    }
    Ok(root as usize)
}

/// Uniform-block layout of the fixed-count collective entry points:
/// `count` elements per rank, rank `r`'s block at element displacement
/// `r * count`.
fn uniform_layout(count: usize, n: usize) -> (Vec<usize>, Vec<isize>) {
    (vec![count; n], (0..n).map(|r| (r * count) as isize).collect())
}

/// Even element split for the segmented allreduce variants: segment `r`
/// of `count` elements over `n` ranks covers `[r·count/n, (r+1)·count/n)`
/// — sizes differ by at most one element and every rank computes
/// identical boundaries.
fn seg_bounds(count: usize, n: usize, r: usize) -> (usize, usize) {
    (r * count / n, (r + 1) * count / n)
}

/// Largest power of two ≤ `n` (n ≥ 1).
fn prev_pow2(n: usize) -> usize {
    if n.is_power_of_two() {
        n
    } else {
        n.next_power_of_two() / 2
    }
}

/// Whether `op` commutes — builtins all do; user ops report their
/// `MPI_Op_create` flag. The selector refuses segment-reordering
/// algorithms for non-commutative ops.
fn op_commutes(ctx: &RankCtx, op: OpId) -> bool {
    let t = ctx.tables.borrow();
    match t.ops.get(op.0).map(|o| &o.kind) {
        Some(crate::core::op::OpKind::User { commute, .. }) => *commute,
        _ => true,
    }
}

// --- non-power-of-two fold (recursive doubling / Rabenseifner) -------------
//
// The first 2r ranks (r = n − prev_pow2(n)) pair up even→odd on phase 0 so
// a power-of-two subset runs the exchange rounds; the folded-out evens
// receive the finished vector on `post_phase`. Virtual-rank mapping is
// MPICH's: odd pair members continue as vrank me/2, the unpaired tail as
// me − r.

fn fold_in(s: &mut Schedule, me: usize, r: usize, op: OpId, count: usize, dt: DtId) -> Option<usize> {
    if me < 2 * r {
        if me % 2 == 0 {
            s.push(Step::SendAccum { to: me + 1, phase: 0, range: None });
            None
        } else {
            s.push(Step::Recv {
                from: me - 1,
                phase: 0,
                action: RecvAction::Combine { op, count, dt },
            });
            Some(me / 2)
        }
    } else {
        Some(me - r)
    }
}

fn fold_out(s: &mut Schedule, me: usize, r: usize, post_phase: i32) {
    if me < 2 * r {
        if me % 2 == 0 {
            s.push(Step::Recv { from: me + 1, phase: post_phase, action: RecvAction::Store });
        } else {
            s.push(Step::SendAccum { to: me - 1, phase: post_phase, range: None });
        }
    }
}

/// Real comm rank of virtual rank `v` under the fold mapping.
fn real_of(v: usize, r: usize) -> usize {
    if v < r {
        2 * v + 1
    } else {
        v + r
    }
}

/// Element range virtual rank `v` holds after recursive halving from mask
/// `p/2` down to `down_to` (inclusive): the lower-bit side keeps the
/// lower half at every level. `down_to = 1` gives the final
/// reduce-scatter range; larger masks give the intermediate ranges the
/// allgather phase re-merges.
fn halved_range(v: usize, p: usize, count: usize, down_to: usize) -> (usize, usize) {
    let (mut lo, mut hi) = (0usize, count);
    let mut mask = p / 2;
    while mask >= down_to {
        let mid = lo + (hi - lo) / 2;
        if v & mask == 0 {
            hi = mid;
        } else {
            lo = mid;
        }
        mask >>= 1;
    }
    (lo, hi)
}

// ---------------------------------------------------------------------------
// Schedule builders
// ---------------------------------------------------------------------------
//
// Each collective has exactly one builder, returning a restartable
// Schedule; the nonblocking entry point submits it one-shot, the
// persistent `*_init` entry point parks it in an Inactive request.

/// Dissemination barrier, one tag phase per round.
fn build_barrier(comm: CommId) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    let mut s = Schedule::new(cc);
    let mut k = 1usize;
    let mut round = 0i32;
    while k < n {
        let dst = (me + k) % n;
        let src = (me + n - k) % n;
        s.push(Step::Send { to: dst, phase: round, data: Vec::new() });
        s.push(Step::Recv { from: src, phase: round, action: RecvAction::Discard });
        k <<= 1;
        round += 1;
    }
    Ok(s)
}

/// `MPI_Ibarrier`.
pub fn ibarrier(comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| submit(ctx, build_barrier(comm)?))
}

/// `MPI_Barrier_init` (MPI-4): persistent barrier. Collective call.
pub fn barrier_init(comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| submit_init(ctx, build_barrier(comm)?))
}

/// Append a binomial-tree broadcast of the accumulator (rooted at comm
/// rank `root`, tag phase `phase`) to `s`.
fn push_bcast_tree(s: &mut Schedule, me: usize, n: usize, root: usize, phase: i32) {
    let vrank = (me + n - root) % n;
    if vrank != 0 {
        let parent_real = (parent_of(vrank) + root) % n;
        s.push(Step::Recv { from: parent_real, phase, action: RecvAction::Store });
    }
    for child in children_of(vrank, n) {
        let child_real = (child + root) % n;
        s.push(Step::SendAccum { to: child_real, phase, range: None });
    }
}

/// Append a binomial-tree reduction of the accumulator toward comm rank
/// `root` on tag phase `phase`.
fn push_reduce_tree(
    s: &mut Schedule,
    me: usize,
    n: usize,
    root: usize,
    phase: i32,
    op: OpId,
    count: usize,
    dt: DtId,
) {
    let vrank = (me + n - root) % n;
    for child in children_of(vrank, n) {
        let child_real = (child + root) % n;
        s.push(Step::Recv {
            from: child_real,
            phase,
            action: RecvAction::Combine { op, count, dt },
        });
    }
    if vrank != 0 {
        let parent_real = (parent_of(vrank) + root) % n;
        s.push(Step::SendAccum { to: parent_real, phase, range: None });
    }
}

/// Binomial-tree broadcast.
fn build_bcast(buf: *mut u8, count: usize, dt: DtId, root: i32, comm: CommId) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let root = check_root(&cc, root)?;
    let n = cc.size();
    let me = cc.my_rank;
    let mut s = Schedule::new(cc);
    if n > 1 {
        if me == root {
            s.prep.push(Prep::PackAccum { buf: buf as usize, displ: 0, count, dt });
        }
        push_bcast_tree(&mut s, me, n, root, 0);
        if me != root {
            s.push(Step::Unpack {
                buf: buf as usize,
                displ: 0,
                count,
                dt,
                range: None,
                from_aux: false,
            });
        }
    }
    Ok(s)
}

/// `MPI_Ibcast`.
pub fn ibcast(buf: *mut u8, count: usize, dt: DtId, root: i32, comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| submit(ctx, build_bcast(buf, count, dt, root, comm)?))
}

/// `MPI_Bcast_init` (MPI-4): the root's buffer is re-read at every
/// start. Collective call.
pub fn bcast_init(buf: *mut u8, count: usize, dt: DtId, root: i32, comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| submit_init(ctx, build_bcast(buf, count, dt, root, comm)?))
}

/// Binomial-tree reduction to `root`.
fn build_reduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    root: i32,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let root = check_root(&cc, root)?;
    let n = cc.size();
    let me = cc.my_rank;
    let contrib = if in_place(sendbuf) && me == root { recvbuf as *const u8 } else { sendbuf };
    let mut s = Schedule::new(cc);
    s.prep.push(Prep::PackAccum { buf: contrib as usize, displ: 0, count, dt });
    push_reduce_tree(&mut s, me, n, root, 0, op, count, dt);
    if me == root {
        s.push(Step::Unpack {
            buf: recvbuf as usize,
            displ: 0,
            count,
            dt,
            range: None,
            from_aux: false,
        });
    }
    Ok(s)
}

/// `MPI_Ireduce`.
pub fn ireduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| submit(ctx, build_reduce(sendbuf, recvbuf, count, dt, op, root, comm)?))
}

/// Reduce to comm rank 0, then broadcast — two phases.
fn build_allreduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
    let mut s = Schedule::new(cc);
    s.prep.push(Prep::PackAccum { buf: contrib as usize, displ: 0, count, dt });
    if n > 1 {
        push_reduce_tree(&mut s, me, n, 0, 0, op, count, dt);
        push_bcast_tree(&mut s, me, n, 0, 1);
    }
    s.push(Step::Unpack {
        buf: recvbuf as usize,
        displ: 0,
        count,
        dt,
        range: None,
        from_aux: false,
    });
    Ok(s)
}

/// Ring allreduce: a reduce-scatter ring (phase 0, n−1 rounds) then an
/// allgather ring (phase 1, n−1 rounds). Bandwidth-optimal — every rank
/// moves ~2·(n−1)/n of the vector no matter how large n gets — at the
/// cost of 2(n−1) serialized rounds, so the selector reserves it for
/// large messages.
fn build_allreduce_ring(
    ctx: &RankCtx,
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
    let esize = packed_len(ctx, 1, dt)?;
    let mut s = Schedule::new(cc);
    s.prep.push(Prep::PackAccum { buf: contrib as usize, displ: 0, count, dt });
    if n > 1 {
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        // Reduce-scatter: round k sends segment (me−k) mod n right and
        // folds segment (me−k−1) mod n from the left; after n−1 rounds
        // segment (me+1) mod n is fully reduced here.
        for k in 0..n - 1 {
            let send_seg = (me + n - k) % n;
            let recv_seg = (me + n - k - 1) % n;
            let (slo, shi) = seg_bounds(count, n, send_seg);
            let (rlo, rhi) = seg_bounds(count, n, recv_seg);
            s.push(Step::SendAccum {
                to: right,
                phase: 0,
                range: Some((slo * esize, (shi - slo) * esize)),
            });
            s.push(Step::Recv {
                from: left,
                phase: 0,
                action: RecvAction::CombineAt {
                    op,
                    offset: rlo * esize,
                    len: (rhi - rlo) * esize,
                    count: rhi - rlo,
                    dt,
                },
            });
        }
        // Allgather: circulate the completed segments once around.
        for k in 0..n - 1 {
            let send_seg = (me + n + 1 - k) % n;
            let recv_seg = (me + n - k) % n;
            let (slo, shi) = seg_bounds(count, n, send_seg);
            let (rlo, rhi) = seg_bounds(count, n, recv_seg);
            s.push(Step::SendAccum {
                to: right,
                phase: 1,
                range: Some((slo * esize, (shi - slo) * esize)),
            });
            s.push(Step::Recv {
                from: left,
                phase: 1,
                action: RecvAction::StoreAt { offset: rlo * esize, len: (rhi - rlo) * esize },
            });
        }
    }
    s.push(Step::Unpack {
        buf: recvbuf as usize,
        displ: 0,
        count,
        dt,
        range: None,
        from_aux: false,
    });
    Ok(s)
}

/// Recursive-doubling allreduce: ⌈log2 n⌉ whole-vector exchange rounds
/// among a power-of-two subset (fold for the rest). Fewest rounds of any
/// variant — the latency algorithm for small messages.
fn build_allreduce_rd(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
    let mut s = Schedule::new(cc);
    s.prep.push(Prep::PackAccum { buf: contrib as usize, displ: 0, count, dt });
    if n > 1 {
        let p = prev_pow2(n);
        let r = n - p;
        let rounds = p.trailing_zeros() as i32;
        if let Some(v) = fold_in(&mut s, me, r, op, count, dt) {
            let mut mask = 1usize;
            let mut phase = 1i32;
            while mask < p {
                let partner = real_of(v ^ mask, r);
                s.push(Step::SendAccum { to: partner, phase, range: None });
                s.push(Step::Recv {
                    from: partner,
                    phase,
                    action: RecvAction::Combine { op, count, dt },
                });
                mask <<= 1;
                phase += 1;
            }
        }
        fold_out(&mut s, me, r, 1 + rounds);
    }
    s.push(Step::Unpack {
        buf: recvbuf as usize,
        displ: 0,
        count,
        dt,
        range: None,
        from_aux: false,
    });
    Ok(s)
}

/// Rabenseifner allreduce: recursive-halving reduce-scatter, then a
/// recursive-doubling allgather re-merging the halves (fold for
/// non-power-of-two). Log rounds like recursive doubling, but each round
/// moves half the remaining data — the mid-size algorithm.
fn build_allreduce_rabenseifner(
    ctx: &RankCtx,
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
    let esize = packed_len(ctx, 1, dt)?;
    let mut s = Schedule::new(cc);
    s.prep.push(Prep::PackAccum { buf: contrib as usize, displ: 0, count, dt });
    if n > 1 {
        let p = prev_pow2(n);
        let r = n - p;
        let rounds = p.trailing_zeros() as i32;
        if let Some(v) = fold_in(&mut s, me, r, op, count, dt) {
            // Reduce-scatter by recursive halving, masks p/2 → 1.
            let mut mask = p / 2;
            let mut phase = 1i32;
            while mask >= 1 {
                let vp = v ^ mask;
                let partner = real_of(vp, r);
                let (klo, khi) = halved_range(v, p, count, mask);
                let (glo, ghi) = halved_range(vp, p, count, mask);
                s.push(Step::SendAccum {
                    to: partner,
                    phase,
                    range: Some((glo * esize, (ghi - glo) * esize)),
                });
                s.push(Step::Recv {
                    from: partner,
                    phase,
                    action: RecvAction::CombineAt {
                        op,
                        offset: klo * esize,
                        len: (khi - klo) * esize,
                        count: khi - klo,
                        dt,
                    },
                });
                mask >>= 1;
                phase += 1;
            }
            // Allgather by recursive doubling, masks 1 → p/2; each step
            // swaps the sibling interval at that recursion level.
            let mut mask = 1usize;
            while mask < p {
                let vp = v ^ mask;
                let partner = real_of(vp, r);
                let (mlo, mhi) = halved_range(v, p, count, mask);
                let (tlo, thi) = halved_range(vp, p, count, mask);
                s.push(Step::SendAccum {
                    to: partner,
                    phase,
                    range: Some((mlo * esize, (mhi - mlo) * esize)),
                });
                s.push(Step::Recv {
                    from: partner,
                    phase,
                    action: RecvAction::StoreAt {
                        offset: tlo * esize,
                        len: (thi - tlo) * esize,
                    },
                });
                mask <<= 1;
                phase += 1;
            }
        }
        fold_out(&mut s, me, r, 1 + 2 * rounds);
    }
    s.push(Step::Unpack {
        buf: recvbuf as usize,
        displ: 0,
        count,
        dt,
        range: None,
        from_aux: false,
    });
    Ok(s)
}

/// Selector-routed allreduce build: consult the forced override / tuning
/// table, build the variant, stamp its algorithm id (trace high byte)
/// and count the selection (pvar registry 20+).
fn build_allreduce_any(
    ctx: &RankCtx,
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<Schedule> {
    use crate::core::obs as ob;
    let n = comm_size(comm)? as usize;
    let bytes = packed_len(ctx, count, dt)?;
    let force = ctx.state.borrow().coll_algo.allreduce;
    let algo = super::pick_allreduce(force, bytes, n, op_commutes(ctx, op));
    let (mut s, id) = match algo {
        super::ALLREDUCE_RING => (
            build_allreduce_ring(ctx, sendbuf, recvbuf, count, dt, op, comm)?,
            ob::COLL_ALGO_RING,
        ),
        super::ALLREDUCE_RECURSIVE_DOUBLING => (
            build_allreduce_rd(sendbuf, recvbuf, count, dt, op, comm)?,
            ob::COLL_ALGO_RECURSIVE_DOUBLING,
        ),
        super::ALLREDUCE_RABENSEIFNER => (
            build_allreduce_rabenseifner(ctx, sendbuf, recvbuf, count, dt, op, comm)?,
            ob::COLL_ALGO_RABENSEIFNER,
        ),
        _ => (
            build_allreduce(sendbuf, recvbuf, count, dt, op, comm)?,
            ob::COLL_ALGO_BINOMIAL,
        ),
    };
    s.algo = id;
    ctx.obs.note_coll_algo(id);
    Ok(s)
}

/// `MPI_Iallreduce`.
pub fn iallreduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let s = build_allreduce_any(ctx, sendbuf, recvbuf, count, dt, op, comm)?;
        submit(ctx, s)
    })
}

/// `MPI_Allreduce_init` (MPI-4): contributions are re-packed from the
/// send buffer at every start. Collective call. The algorithm is chosen
/// once, at init time, and reused across starts.
pub fn allreduce_init(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let s = build_allreduce_any(ctx, sendbuf, recvbuf, count, dt, op, comm)?;
        submit_init(ctx, s)
    })
}

/// Linear rooted gather (displacements in recvtype extents, MPI-style).
#[allow(clippy::too_many_arguments)]
fn build_gatherv(
    ctx: &RankCtx,
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let root = check_root(&cc, root)?;
    let n = cc.size();
    let me = cc.my_rank;
    if me == root && (recvcounts.len() != n || displs.len() != n) {
        return Err(err!(MPI_ERR_COUNT));
    }
    let mut s = Schedule::new(cc);
    if me == root {
        let rext = extent_of(ctx, recvtype)?;
        if !in_place(sendbuf) {
            s.prep.push(Prep::Exchange {
                sbuf: sendbuf as usize,
                sdispl: 0,
                scount: sendcount,
                sdt: sendtype,
                dbuf: recvbuf as usize,
                ddispl: rext * displs[me],
                dcount: recvcounts[me],
                ddt: recvtype,
            });
        }
        for r in 0..n {
            if r == root {
                continue;
            }
            s.push(Step::Recv {
                from: r,
                phase: 0,
                action: RecvAction::Unpack {
                    buf: recvbuf as usize,
                    displ: rext * displs[r],
                    count: recvcounts[r],
                    dt: recvtype,
                },
            });
        }
    } else {
        let idx = s.next_idx();
        s.push(Step::Send { to: root, phase: 0, data: Vec::new() });
        s.prep.push(Prep::PackStep {
            idx,
            buf: sendbuf as usize,
            displ: 0,
            count: sendcount,
            dt: sendtype,
        });
    }
    Ok(s)
}

/// `MPI_Igatherv`.
#[allow(clippy::too_many_arguments)]
pub fn igatherv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let s = build_gatherv(ctx, sendbuf, sendcount, sendtype, recvbuf, recvcounts, displs,
            recvtype, root, comm)?;
        submit(ctx, s)
    })
}

/// `MPI_Igather`.
#[allow(clippy::too_many_arguments)]
pub fn igather(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let (counts, displs) = uniform_layout(recvcount, n);
    igatherv(sendbuf, sendcount, sendtype, recvbuf, &counts, &displs, recvtype, root, comm)
}

/// `MPI_Gather_init` (MPI-4). Collective call.
#[allow(clippy::too_many_arguments)]
pub fn gather_init(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let (counts, displs) = uniform_layout(recvcount, n);
    with_ctx(|ctx| {
        let s = build_gatherv(ctx, sendbuf, sendcount, sendtype, recvbuf, &counts, &displs,
            recvtype, root, comm)?;
        submit_init(ctx, s)
    })
}

/// Linear rooted scatter (displacements in sendtype extents).
#[allow(clippy::too_many_arguments)]
fn build_scatterv(
    ctx: &RankCtx,
    sendbuf: *const u8,
    sendcounts: &[usize],
    displs: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let root = check_root(&cc, root)?;
    let n = cc.size();
    let me = cc.my_rank;
    if me == root && (sendcounts.len() != n || displs.len() != n) {
        return Err(err!(MPI_ERR_COUNT));
    }
    let mut s = Schedule::new(cc);
    if me == root {
        let sext = extent_of(ctx, sendtype)?;
        for r in 0..n {
            if r == root {
                // In place: the root's block stays where it is.
                if !in_place(recvbuf as *const u8) {
                    s.prep.push(Prep::Exchange {
                        sbuf: sendbuf as usize,
                        sdispl: sext * displs[r],
                        scount: sendcounts[r],
                        sdt: sendtype,
                        dbuf: recvbuf as usize,
                        ddispl: 0,
                        dcount: recvcount,
                        ddt: recvtype,
                    });
                }
            } else {
                let idx = s.next_idx();
                s.push(Step::Send { to: r, phase: 0, data: Vec::new() });
                s.prep.push(Prep::PackStep {
                    idx,
                    buf: sendbuf as usize,
                    displ: sext * displs[r],
                    count: sendcounts[r],
                    dt: sendtype,
                });
            }
        }
    } else {
        s.push(Step::Recv {
            from: root,
            phase: 0,
            action: RecvAction::Unpack {
                buf: recvbuf as usize,
                displ: 0,
                count: recvcount,
                dt: recvtype,
            },
        });
    }
    Ok(s)
}

/// `MPI_Iscatterv`.
#[allow(clippy::too_many_arguments)]
pub fn iscatterv(
    sendbuf: *const u8,
    sendcounts: &[usize],
    displs: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let s = build_scatterv(ctx, sendbuf, sendcounts, displs, sendtype, recvbuf, recvcount,
            recvtype, root, comm)?;
        submit(ctx, s)
    })
}

/// `MPI_Iscatter`.
#[allow(clippy::too_many_arguments)]
pub fn iscatter(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let (counts, displs) = uniform_layout(sendcount, n);
    iscatterv(sendbuf, &counts, &displs, sendtype, recvbuf, recvcount, recvtype, root, comm)
}

/// `MPI_Scatter_init` (MPI-4): the root's blocks are re-packed at every
/// start. Collective call.
#[allow(clippy::too_many_arguments)]
pub fn scatter_init(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let (counts, displs) = uniform_layout(sendcount, n);
    with_ctx(|ctx| {
        let s = build_scatterv(ctx, sendbuf, &counts, &displs, sendtype, recvbuf, recvcount,
            recvtype, root, comm)?;
        submit_init(ctx, s)
    })
}

/// Gather packed blocks into the accumulator at comm rank 0 (phase 0),
/// broadcast it (phase 1), unpack every block locally.
#[allow(clippy::too_many_arguments)]
fn build_allgatherv(
    ctx: &RankCtx,
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    if recvcounts.len() != n || displs.len() != n {
        return Err(err!(MPI_ERR_COUNT));
    }
    let rext = extent_of(ctx, recvtype)?;
    let per = packed_len(ctx, 1, recvtype)?;
    // Packed block offsets in the accumulator.
    let mut offs = Vec::with_capacity(n);
    let mut total = 0usize;
    for &c in recvcounts {
        offs.push(total);
        total += per * c;
    }
    // My contribution (for MPI_IN_PLACE: my block of recvbuf).
    let (own_buf, own_displ, own_count, own_dt) = if in_place(sendbuf) {
        (recvbuf as usize, rext * displs[me], recvcounts[me], recvtype)
    } else {
        (sendbuf as usize, 0, sendcount, sendtype)
    };
    let mut s = Schedule::new(cc);
    if me == 0 {
        s.prep.push(Prep::ClearAccum { len: total });
        s.prep.push(Prep::PackAccumAt {
            off: offs[0],
            buf: own_buf,
            displ: own_displ,
            count: own_count,
            dt: own_dt,
        });
        for r in 1..n {
            s.push(Step::Recv {
                from: r,
                phase: 0,
                action: RecvAction::StoreAt { offset: offs[r], len: per * recvcounts[r] },
            });
        }
    } else {
        let idx = s.next_idx();
        s.push(Step::Send { to: 0, phase: 0, data: Vec::new() });
        s.prep.push(Prep::PackStep {
            idx,
            buf: own_buf,
            displ: own_displ,
            count: own_count,
            dt: own_dt,
        });
    }
    push_bcast_tree(&mut s, me, n, 0, 1);
    for r in 0..n {
        s.push(Step::Unpack {
            buf: recvbuf as usize,
            displ: rext * displs[r],
            count: recvcounts[r],
            dt: recvtype,
            range: Some((offs[r], per * recvcounts[r])),
            from_aux: false,
        });
    }
    Ok(s)
}

/// Ring allgather(v): every rank's block travels once around the ring —
/// n−1 rounds on one phase, each rank forwarding the newest block it
/// holds. Total bytes moved per rank ≈ the full gathered size no matter
/// the root topology, with no rank-0 hotspot; the selector picks it over
/// gather+bcast for large totals.
#[allow(clippy::too_many_arguments)]
fn build_allgatherv_ring(
    ctx: &RankCtx,
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    if recvcounts.len() != n || displs.len() != n {
        return Err(err!(MPI_ERR_COUNT));
    }
    let rext = extent_of(ctx, recvtype)?;
    let per = packed_len(ctx, 1, recvtype)?;
    let mut offs = Vec::with_capacity(n);
    let mut total = 0usize;
    for &c in recvcounts {
        offs.push(total);
        total += per * c;
    }
    let (own_buf, own_displ, own_count, own_dt) = if in_place(sendbuf) {
        (recvbuf as usize, rext * displs[me], recvcounts[me], recvtype)
    } else {
        (sendbuf as usize, 0, sendcount, sendtype)
    };
    let mut s = Schedule::new(cc);
    s.prep.push(Prep::ClearAccum { len: total });
    s.prep.push(Prep::PackAccumAt {
        off: offs[me],
        buf: own_buf,
        displ: own_displ,
        count: own_count,
        dt: own_dt,
    });
    if n > 1 {
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        // Round k forwards block (me−k) mod n right and stores block
        // (me−k−1) mod n from the left.
        for k in 0..n - 1 {
            let send_blk = (me + n - k) % n;
            let recv_blk = (me + n - 1 - k) % n;
            s.push(Step::SendAccum {
                to: right,
                phase: 0,
                range: Some((offs[send_blk], per * recvcounts[send_blk])),
            });
            s.push(Step::Recv {
                from: left,
                phase: 0,
                action: RecvAction::StoreAt {
                    offset: offs[recv_blk],
                    len: per * recvcounts[recv_blk],
                },
            });
        }
    }
    for r in 0..n {
        s.push(Step::Unpack {
            buf: recvbuf as usize,
            displ: rext * displs[r],
            count: recvcounts[r],
            dt: recvtype,
            range: Some((offs[r], per * recvcounts[r])),
            from_aux: false,
        });
    }
    Ok(s)
}

/// Selector-routed allgatherv build (`iallgather` lands here too via the
/// uniform layout).
#[allow(clippy::too_many_arguments)]
fn build_allgatherv_any(
    ctx: &RankCtx,
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<Schedule> {
    use crate::core::obs as ob;
    let n = comm_size(comm)? as usize;
    let per = packed_len(ctx, 1, recvtype)?;
    let total: usize = recvcounts.iter().map(|&c| per * c).sum();
    let force = ctx.state.borrow().coll_algo.allgather;
    let algo = super::pick_allgather(force, total, n);
    let (mut s, id) = match algo {
        super::ALLGATHER_RING => (
            build_allgatherv_ring(ctx, sendbuf, sendcount, sendtype, recvbuf, recvcounts,
                displs, recvtype, comm)?,
            ob::COLL_ALGO_RING,
        ),
        _ => (
            build_allgatherv(ctx, sendbuf, sendcount, sendtype, recvbuf, recvcounts, displs,
                recvtype, comm)?,
            ob::COLL_ALGO_BINOMIAL,
        ),
    };
    s.algo = id;
    ctx.obs.note_coll_algo(id);
    Ok(s)
}

/// `MPI_Iallgatherv`.
#[allow(clippy::too_many_arguments)]
pub fn iallgatherv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let s = build_allgatherv_any(ctx, sendbuf, sendcount, sendtype, recvbuf, recvcounts,
            displs, recvtype, comm)?;
        submit(ctx, s)
    })
}

/// `MPI_Iallgather`.
#[allow(clippy::too_many_arguments)]
pub fn iallgather(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let (counts, displs) = uniform_layout(recvcount, n);
    iallgatherv(sendbuf, sendcount, sendtype, recvbuf, &counts, &displs, recvtype, comm)
}

/// Pairwise exchange: one eager send and one parked receive per peer,
/// all on phase 0 (peer identity disambiguates).
///
/// `MPI_IN_PLACE` works because *all* send blocks are packed at arm
/// time, before any receive step can overwrite `recvbuf`: the in-place
/// send side is simply the receive side's layout.
fn build_alltoallw(args: &super::AlltoallwArgs, comm: CommId) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    let inp = in_place(args.sendbuf);
    if args.recvcounts.len() != n || (!inp && args.sendcounts.len() != n) {
        return Err(err!(MPI_ERR_COUNT));
    }
    // Resolve the send side: for MPI_IN_PLACE the data to distribute
    // sits in recvbuf with the receive-side layout.
    let (sbuf, scounts, sdispls, stypes) = if inp {
        (args.recvbuf as *const u8, &args.recvcounts, &args.rdispls, &args.recvtypes)
    } else {
        (args.sendbuf, &args.sendcounts, &args.sdispls, &args.sendtypes)
    };
    let mut s = Schedule::new(cc);
    for r in 0..n {
        if r == me {
            // Self-exchange: local pack/unpack at arm time.
            s.prep.push(Prep::Exchange {
                sbuf: sbuf as usize,
                sdispl: sdispls[r],
                scount: scounts[r],
                sdt: stypes[r],
                dbuf: args.recvbuf as usize,
                ddispl: args.rdispls[r],
                dcount: args.recvcounts[r],
                ddt: args.recvtypes[r],
            });
        } else {
            let idx = s.next_idx();
            s.push(Step::Send { to: r, phase: 0, data: Vec::new() });
            s.prep.push(Prep::PackStep {
                idx,
                buf: sbuf as usize,
                displ: sdispls[r],
                count: scounts[r],
                dt: stypes[r],
            });
        }
    }
    for r in 0..n {
        if r == me {
            continue;
        }
        s.push(Step::Recv {
            from: r,
            phase: 0,
            action: RecvAction::Unpack {
                buf: args.recvbuf as usize,
                displ: args.rdispls[r],
                count: args.recvcounts[r],
                dt: args.recvtypes[r],
            },
        });
    }
    Ok(s)
}

/// `MPI_Ialltoallw` over the schedule engine.
pub fn ialltoallw(args: &super::AlltoallwArgs, comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| submit(ctx, build_alltoallw(args, comm)?))
}

/// Expand `MPI_Ialltoallv`-style arguments (displacements in type
/// extents) into [`super::AlltoallwArgs`] (displacements in bytes).
#[allow(clippy::too_many_arguments)]
fn alltoallv_args(
    sendbuf: *const u8,
    sendcounts: &[usize],
    sdispls_elems: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    rdispls_elems: &[isize],
    recvtype: DtId,
    n: usize,
) -> RC<super::AlltoallwArgs> {
    let sext = crate::core::datatype::type_get_extent(sendtype)?.1;
    let rext = crate::core::datatype::type_get_extent(recvtype)?.1;
    Ok(super::AlltoallwArgs {
        sendbuf,
        sendcounts: sendcounts.to_vec(),
        sdispls: sdispls_elems.iter().map(|&d| d * sext).collect(),
        sendtypes: vec![sendtype; n],
        recvbuf,
        recvcounts: recvcounts.to_vec(),
        rdispls: rdispls_elems.iter().map(|&d| d * rext).collect(),
        recvtypes: vec![recvtype; n],
    })
}

/// `MPI_Ialltoallv` (displacements in type extents).
#[allow(clippy::too_many_arguments)]
pub fn ialltoallv(
    sendbuf: *const u8,
    sendcounts: &[usize],
    sdispls_elems: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    rdispls_elems: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let args = alltoallv_args(sendbuf, sendcounts, sdispls_elems, sendtype, recvbuf, recvcounts,
        rdispls_elems, recvtype, n)?;
    ialltoallw(&args, comm)
}

/// Bruck alltoall (uniform blocks): rotate blocks locally so block `j`
/// targets rank (me+j) mod n, run ⌈log2 n⌉ rounds where round `k` ships
/// every block whose index has bit `k` set to the rank 2^k to the right
/// (one envelope per round via the band table), then unrotate into the
/// receive buffer. ⌈log2 n⌉ envelopes instead of pairwise's n−1 — the
/// small-block / high-rank algorithm.
#[allow(clippy::too_many_arguments)]
fn build_alltoall_bruck(
    ctx: &RankCtx,
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    let (sbuf, scount, stype) = if in_place(sendbuf) {
        (recvbuf as *const u8, recvcount, recvtype)
    } else {
        (sendbuf, sendcount, sendtype)
    };
    let blk = packed_len(ctx, scount, stype)?;
    let sext = extent_of(ctx, stype)?;
    let rext = extent_of(ctx, recvtype)?;
    let mut s = Schedule::new(cc);
    // Rotation: accum block j = my send block for rank (me+j) mod n. All
    // packing happens at arm time, before any receive step can overwrite
    // recvbuf — which is what makes MPI_IN_PLACE safe (same argument as
    // the pairwise builder).
    s.prep.push(Prep::ClearAccum { len: blk * n });
    for j in 0..n {
        let dst_rank = (me + j) % n;
        s.prep.push(Prep::PackAccumAt {
            off: j * blk,
            buf: sbuf as usize,
            displ: sext * (dst_rank * scount) as isize,
            count: scount,
            dt: stype,
        });
    }
    let mut k = 1usize;
    let mut phase = 0i32;
    while k < n {
        let band = s.bands.len();
        s.bands.push((0..n).filter(|j| j & k != 0).map(|j| (j * blk, blk)).collect());
        // Program order guarantees the send packs these blocks before
        // the receive overwrites the same indices.
        s.push(Step::SendAccumBands { to: (me + k) % n, phase, band });
        s.push(Step::Recv {
            from: (me + n - k) % n,
            phase,
            action: RecvAction::ScatterBands { band },
        });
        k <<= 1;
        phase += 1;
    }
    // Unrotation: the block from source rank i sits at index (me−i) mod n.
    for i in 0..n {
        let j = (me + n - i) % n;
        s.push(Step::Unpack {
            buf: recvbuf as usize,
            displ: rext * (i * recvcount) as isize,
            count: recvcount,
            dt: recvtype,
            range: Some((j * blk, blk)),
            from_aux: false,
        });
    }
    Ok(s)
}

/// Selector-routed uniform alltoall build (Bruck vs pairwise; the v/w
/// entry points always take the pairwise builder, whose layouts Bruck's
/// rotation cannot express).
#[allow(clippy::too_many_arguments)]
fn build_alltoall_any(
    ctx: &RankCtx,
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<Schedule> {
    use crate::core::obs as ob;
    let n = comm_size(comm)? as usize;
    let blk = packed_len(ctx, recvcount, recvtype)?;
    let force = ctx.state.borrow().coll_algo.alltoall;
    let algo = super::pick_alltoall(force, blk, n);
    if algo == super::ALLTOALL_BRUCK {
        let mut s = build_alltoall_bruck(ctx, sendbuf, sendcount, sendtype, recvbuf, recvcount,
            recvtype, comm)?;
        s.algo = ob::COLL_ALGO_BRUCK;
        ctx.obs.note_coll_algo(ob::COLL_ALGO_BRUCK);
        return Ok(s);
    }
    let (scounts, sdispls) = uniform_layout(sendcount, n);
    let (rcounts, rdispls) = uniform_layout(recvcount, n);
    let args = alltoallv_args(sendbuf, &scounts, &sdispls, sendtype, recvbuf, &rcounts, &rdispls,
        recvtype, n)?;
    let mut s = build_alltoallw(&args, comm)?;
    s.algo = ob::COLL_ALGO_PAIRWISE;
    ctx.obs.note_coll_algo(ob::COLL_ALGO_PAIRWISE);
    Ok(s)
}

/// `MPI_Ialltoall`.
#[allow(clippy::too_many_arguments)]
pub fn ialltoall(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let s = build_alltoall_any(ctx, sendbuf, sendcount, sendtype, recvbuf, recvcount,
            recvtype, comm)?;
        submit(ctx, s)
    })
}

/// `MPI_Alltoall_init` (MPI-4): every send block is re-packed at every
/// start. Collective call. The algorithm is chosen once, at init time.
#[allow(clippy::too_many_arguments)]
pub fn alltoall_init(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let s = build_alltoall_any(ctx, sendbuf, sendcount, sendtype, recvbuf, recvcount,
            recvtype, comm)?;
        submit_init(ctx, s)
    })
}

/// Inclusive scan, linear chain.
fn build_scan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<Schedule> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    let me = cc.my_rank;
    let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
    let mut s = Schedule::new(cc);
    s.prep.push(Prep::PackAccum { buf: contrib as usize, displ: 0, count, dt });
    if me > 0 {
        s.push(Step::Recv {
            from: me - 1,
            phase: 0,
            action: RecvAction::Combine { op, count, dt },
        });
    }
    if me + 1 < n {
        s.push(Step::SendAccum { to: me + 1, phase: 0, range: None });
    }
    s.push(Step::Unpack {
        buf: recvbuf as usize,
        displ: 0,
        count,
        dt,
        range: None,
        from_aux: false,
    });
    Ok(s)
}

/// `MPI_Iscan` (inclusive, linear chain).
pub fn iscan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| submit(ctx, build_scan(sendbuf, recvbuf, count, dt, op, comm)?))
}

/// `MPI_Iexscan` (exclusive; rank 0's recvbuf stays untouched).
pub fn iexscan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let mut s = Schedule::new(cc);
        // Own contribution.
        s.prep.push(Prep::PackAccum { buf: contrib as usize, displ: 0, count, dt });
        if me > 0 {
            s.push(Step::Recv { from: me - 1, phase: 0, action: RecvAction::StoreAux });
        }
        if me + 1 < n {
            if me > 0 {
                // forward = op(partial, own)
                s.push(Step::FoldAux { op, count, dt });
            }
            s.push(Step::SendAccum { to: me + 1, phase: 0, range: None });
        }
        if me > 0 {
            s.push(Step::Unpack {
                buf: recvbuf as usize,
                displ: 0,
                count,
                dt,
                range: None,
                from_aux: true,
            });
        }
        submit(ctx, s)
    })
}

/// `MPI_Ireduce_scatter_block`: reduce the full vector to comm rank 0
/// (phase 0), scatter the per-rank blocks from there (phase 1).
pub fn ireduce_scatter_block(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    recvcount: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        let total = recvcount * n;
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let blk = packed_len(ctx, recvcount, dt)?;
        let mut s = Schedule::new(cc);
        s.prep.push(Prep::PackAccum { buf: contrib as usize, displ: 0, count: total, dt });
        push_reduce_tree(&mut s, me, n, 0, 0, op, total, dt);
        if me == 0 {
            for r in 1..n {
                s.push(Step::SendAccum { to: r, phase: 1, range: Some((r * blk, blk)) });
            }
            s.push(Step::Unpack {
                buf: recvbuf as usize,
                displ: 0,
                count: recvcount,
                dt,
                range: Some((0, blk)),
                from_aux: false,
            });
        } else {
            s.push(Step::Recv {
                from: 0,
                phase: 1,
                action: RecvAction::Unpack {
                    buf: recvbuf as usize,
                    displ: 0,
                    count: recvcount,
                    dt,
                },
            });
        }
        submit(ctx, s)
    })
}
