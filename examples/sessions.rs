//! Sessions quick-start (MPI-4): initialize MPI **without `MPI_Init`**,
//! discover process sets, derive a communicator with no parent, and
//! compute over it — the library-friendly initialization story of
//! MPI-4 §11, against the standard ABI.
//!
//! ```bash
//! cargo run --release --example sessions
//! ```

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::native_abi::NativeAbi;

// init → pset → group → comm, never calling MPI_Init.
fn app<A: MpiAbi>(_rank: usize) -> Vec<String> {
    let mut log = Vec::new();

    // 1. A session is this component's own init epoch.
    let mut session = A::session_null();
    A::session_init(A::info_null(), A::errhandler_return(), &mut session);
    log.push(format!("initialized via sessions: MPI_Initialized = {}", A::initialized()));

    // 2. Discover the process sets the launcher exposes.
    let mut n = 0;
    A::session_get_num_psets(session, &mut n);
    for i in 0..n {
        let mut name = String::new();
        A::session_get_nth_pset(session, i, &mut name);
        let mut info = A::info_null();
        A::session_get_pset_info(session, &name, &mut info);
        let (mut size, mut flag) = (String::new(), false);
        A::info_get(info, "mpi_size", &mut size, &mut flag);
        A::info_free(&mut info);
        log.push(format!("pset {i}: {name} (mpi_size = {size})"));
    }

    // 3. Group from a pset, communicator from the group — no parent
    //    comm; the tag string disambiguates concurrent creations.
    let mut group = unsafe { std::mem::zeroed::<A::Group>() };
    A::group_from_session_pset(session, "mpi://WORLD", &mut group);
    let mut comm = A::comm_null();
    A::comm_create_from_group(group, "example://sessions", A::info_null(),
        A::errhandler_return(), &mut comm);
    A::group_free(&mut group);

    // 4. The derived comm is a full communicator.
    let (mut size, mut rank) = (0, 0);
    A::comm_size(comm, &mut size);
    A::comm_rank(comm, &mut rank);
    let mine = (rank + 1) as i64;
    let mut sum = 0i64;
    A::allreduce(
        &mine as *const i64 as *const u8,
        &mut sum as *mut i64 as *mut u8,
        1,
        A::datatype(Dt::Int64),
        A::op(OpName::Sum),
        comm,
    );
    log.push(format!("rank {rank}/{size}: sum(1..={size}) = {sum}"));

    // 5. Tear down. MPI_Finalized turns true at the last finalize.
    A::comm_free(&mut comm);
    A::session_finalize(&mut session);
    log.push(format!("session closed: MPI_Finalized = {}", A::finalized()));
    log
}

fn main() {
    let logs = run_job_ok(JobSpec::new(4), app::<NativeAbi>);
    for (rank, log) in logs.into_iter().enumerate() {
        for line in log {
            println!("[rank {rank}] {line}");
        }
    }
}
