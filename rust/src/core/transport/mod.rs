//! The shared-memory "network" between ranks.
//!
//! Two interchangeable transports model the paper's UCX/OFI sensitivity
//! (Table 1 note: "build options unrelated to ABI — the shared-memory
//! performance of UCX versus OFI — have a significant impact"):
//!
//! * [`TransportKind::Spsc`] — per-pair lock-free rings (fast, "UCX").
//! * [`TransportKind::Mutex`] — per-rank locked queues (slow, "OFI").
//!
//! The fabric is ABI-agnostic: it moves [`Envelope`]s of packed bytes.

pub mod envelope;
pub mod mutex_queue;
pub mod spsc;

pub use envelope::{Envelope, MsgKind, Payload, INLINE_CAP};

use std::sync::atomic::{AtomicI64, Ordering};

use mutex_queue::MutexQueue;
use spsc::Spsc;

/// Which shared-memory transport a world uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Lock-free SPSC rings per rank pair — the fast path ("UCX shm").
    Spsc,
    /// Mutex-guarded MPSC queue per rank — the slow path ("OFI shm").
    Mutex,
}

impl TransportKind {
    /// Parse a CLI/env transport name (`spsc|ucx|fast`, `mutex|ofi|slow`).
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "spsc" | "ucx" | "fast" => Some(TransportKind::Spsc),
            "mutex" | "ofi" | "slow" => Some(TransportKind::Mutex),
            _ => None,
        }
    }

    /// Canonical name (for reports and tables).
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Spsc => "spsc",
            TransportKind::Mutex => "mutex",
        }
    }
}

/// Capacity of each SPSC ring (envelopes). Must exceed the largest
/// send-window used by apps/benches (osu_mbw_mr uses 64) with slack so
/// senders rarely hit backpressure.
pub const SPSC_CAPACITY: usize = 1024;

/// The full fabric: every rank's inbound queues.
pub enum Fabric {
    /// `rings[dst][src]` — inbound ring at `dst` from `src`.
    Spsc {
        /// Per-ordered-pair rings, indexed `[dst][src]`.
        rings: Vec<Vec<Spsc<Envelope>>>,
        /// Per-destination doorbell: an approximate count of envelopes
        /// inbound at `dst` across all of its rings. Senders increment
        /// after a successful push; the receiver decrements by what it
        /// drained. Makes [`Fabric::inbound_empty`] O(1) instead of an
        /// O(ranks) ring scan — the scan made every idle progress tick
        /// O(ranks²) job-wide, which dominates at 64–512 thread-ranks.
        /// May transiently read stale (a push's increment lands a beat
        /// later), never permanently: a spin loop re-checks next tick.
        doorbell: Vec<AtomicI64>,
        /// World size.
        size: usize,
    },
    /// `queues[dst]` — single locked inbound queue at `dst`.
    Mutex {
        /// One inbound queue per rank.
        queues: Vec<MutexQueue>,
        /// World size.
        size: usize,
    },
}

impl Fabric {
    /// Build the fabric for a `size`-rank world.
    pub fn new(kind: TransportKind, size: usize) -> Fabric {
        match kind {
            TransportKind::Spsc => Fabric::Spsc {
                rings: (0..size)
                    .map(|_| (0..size).map(|_| Spsc::new(SPSC_CAPACITY)).collect())
                    .collect(),
                doorbell: (0..size).map(|_| AtomicI64::new(0)).collect(),
                size,
            },
            TransportKind::Mutex => {
                Fabric::Mutex { queues: (0..size).map(|_| MutexQueue::new()).collect(), size }
            }
        }
    }

    /// Which transport this fabric is.
    pub fn kind(&self) -> TransportKind {
        match self {
            Fabric::Spsc { .. } => TransportKind::Spsc,
            Fabric::Mutex { .. } => TransportKind::Mutex,
        }
    }

    /// World size the fabric was built for.
    pub fn size(&self) -> usize {
        match self {
            Fabric::Spsc { size, .. } | Fabric::Mutex { size, .. } => *size,
        }
    }

    /// Try to deliver `env` to `dst`'s inbound queue. On the bounded SPSC
    /// transport a full ring returns the envelope for retry (the caller
    /// must progress its own inbound traffic and retry — backpressure).
    ///
    /// Caller discipline: only the thread owning world-rank `env.src` may
    /// send from that src on the SPSC transport.
    #[inline]
    pub fn try_send(&self, dst: usize, env: Envelope) -> Result<(), Envelope> {
        match self {
            Fabric::Spsc { rings, doorbell, .. } => {
                let src = env.src as usize;
                rings[dst][src].push(env).map(|()| {
                    // Ring the doorbell only after the push landed; the
                    // counter needs atomicity, not ordering (staleness
                    // is tolerated, see the field doc).
                    doorbell[dst].fetch_add(1, Ordering::Relaxed);
                })
            }
            Fabric::Mutex { queues, .. } => {
                queues[dst].push(env);
                Ok(())
            }
        }
    }

    /// Drain all messages currently inbound at `dst` into `out`, in a
    /// per-sender FIFO order. Only `dst`'s thread may call this.
    #[inline]
    pub fn poll_into(&self, dst: usize, out: &mut Vec<Envelope>) {
        match self {
            Fabric::Spsc { rings, doorbell, .. } => {
                let before = out.len();
                for q in &rings[dst] {
                    while let Some(e) = q.pop() {
                        out.push(e);
                    }
                }
                let drained = (out.len() - before) as i64;
                if drained > 0 {
                    // May transiently drive the counter negative (we can
                    // drain a push whose increment hasn't landed yet);
                    // `inbound_empty` treats <= 0 as empty.
                    doorbell[dst].fetch_sub(drained, Ordering::Relaxed);
                }
            }
            Fabric::Mutex { queues, .. } => queues[dst].drain_into(out),
        }
    }

    /// `true` if nothing is inbound at `dst` (cheap; used to avoid
    /// allocating in tight progress loops).
    #[inline]
    pub fn inbound_empty(&self, dst: usize) -> bool {
        match self {
            Fabric::Spsc { doorbell, .. } => doorbell[dst].load(Ordering::Relaxed) <= 0,
            Fabric::Mutex { queues, .. } => queues[dst].is_empty(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(src: u32, tag: i32) -> Envelope {
        Envelope { src, context: 0, tag, kind: MsgKind::Eager, seq: 0, payload: Payload::empty() }
    }

    #[test]
    fn kind_parse() {
        assert_eq!(TransportKind::parse("ucx"), Some(TransportKind::Spsc));
        assert_eq!(TransportKind::parse("ofi"), Some(TransportKind::Mutex));
        assert_eq!(TransportKind::parse("bogus"), None);
    }

    #[test]
    fn spsc_fabric_routes_by_pair() {
        let f = Fabric::new(TransportKind::Spsc, 3);
        f.try_send(2, env(0, 10)).unwrap();
        f.try_send(2, env(1, 11)).unwrap();
        f.try_send(0, env(2, 12)).unwrap();
        let mut out = Vec::new();
        f.poll_into(2, &mut out);
        assert_eq!(out.len(), 2);
        let mut out0 = Vec::new();
        f.poll_into(0, &mut out0);
        assert_eq!(out0.len(), 1);
        assert_eq!(out0[0].tag, 12);
        assert!(f.inbound_empty(1));
    }

    #[test]
    fn mutex_fabric_routes() {
        let f = Fabric::new(TransportKind::Mutex, 2);
        f.try_send(1, env(0, 5)).unwrap();
        assert!(!f.inbound_empty(1));
        let mut out = Vec::new();
        f.poll_into(1, &mut out);
        assert_eq!(out[0].tag, 5);
        assert!(f.inbound_empty(1));
    }

    #[test]
    fn spsc_doorbell_tracks_inbound() {
        let f = Fabric::new(TransportKind::Spsc, 3);
        assert!(f.inbound_empty(1));
        f.try_send(1, env(0, 1)).unwrap();
        f.try_send(1, env(2, 2)).unwrap();
        assert!(!f.inbound_empty(1));
        let mut out = Vec::new();
        f.poll_into(1, &mut out);
        assert_eq!(out.len(), 2);
        assert!(f.inbound_empty(1));
        // A rejected push must not ring the doorbell.
        let g = Fabric::new(TransportKind::Spsc, 2);
        for i in 0..SPSC_CAPACITY {
            g.try_send(1, env(0, i as i32)).unwrap();
        }
        assert!(g.try_send(1, env(0, -1)).is_err());
        let mut out = Vec::new();
        g.poll_into(1, &mut out);
        assert_eq!(out.len(), SPSC_CAPACITY);
        assert!(g.inbound_empty(1));
    }

    #[test]
    fn spsc_backpressure_surfaces() {
        let f = Fabric::new(TransportKind::Spsc, 2);
        let mut rejected = None;
        for i in 0..(SPSC_CAPACITY + 1) {
            if let Err(e) = f.try_send(1, env(0, i as i32)) {
                rejected = Some(e);
                break;
            }
        }
        let e = rejected.expect("ring must fill at capacity");
        assert_eq!(e.tag, SPSC_CAPACITY as i32);
    }
}
