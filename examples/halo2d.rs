//! 2-D Jacobi halo exchange across ABIs: the stencil result must be
//! bit-identical whichever MPI library carries the halos — and whichever
//! exchange mode (per-sweep sendrecv vs persistent start/wait) drives it.
//!
//! ```bash
//! cargo run --release --example halo2d [ranks] [n] [iters]
//! ```

use mpi_abi::api::MpiAbi;
use mpi_abi::apps::halo::{jacobi, HaloParams};
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::muk::MukMpich;
use mpi_abi::native_abi::NativeAbi;

fn run<A: MpiAbi>(ranks: usize, n: usize, iters: usize, persistent: bool) -> f64 {
    let out = run_job_ok(JobSpec::new(ranks), move |_| {
        A::init();
        let (_, global) = jacobi::<A>(HaloParams { n, iters, persistent });
        A::finalize();
        global
    });
    out[0]
}

fn main() {
    let mut args = std::env::args().skip(1);
    let ranks: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(3);
    let n: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(96);
    let iters: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(50);
    println!("2-D Jacobi: {n}x{n} grid, {ranks} ranks, {iters} sweeps");

    let a = run::<NativeAbi>(ranks, n, iters, false);
    println!("  native std ABI : residual {a:.12}");
    let b = run::<MpichAbi>(ranks, n, iters, false);
    println!("  mpich-like ABI : residual {b:.12}");
    let c = run::<OmpiAbi>(ranks, n, iters, false);
    println!("  ompi-like ABI  : residual {c:.12}");
    let d = run::<MukMpich>(ranks, n, iters, false);
    println!("  muk(mpich)     : residual {d:.12}");
    assert!(a == b && b == c && c == d, "results must be ABI-independent");
    assert!(a > 0.0, "heat must have diffused from the boundary");

    // Persistent halo exchange (MPI-4 Send_init/Recv_init + Startall):
    // same halos, init-once/start-N — the result must not change.
    let e = run::<NativeAbi>(ranks, n, iters, true);
    println!("  abi, persistent: residual {e:.12}");
    let f = run::<MukMpich>(ranks, n, iters, true);
    println!("  muk, persistent: residual {f:.12}");
    assert!(a == e && e == f, "persistent exchange must be bit-identical");
    println!("bit-identical across all libraries and exchange modes ✓");
}
