//! MPI_T tools-interface battery.
//!
//! Exercises the §11 surface through the portable [`MpiAbi`] boundary
//! only, so the same source validates the registry on all five
//! configurations — including both Mukautuva stacks, where every call
//! crosses the WRAP vtable. Three angles:
//!
//! * **enumeration** — the cvar/pvar registries are a fixed, ordered
//!   ABI surface: exact counts, names, classes, scopes;
//! * **error paths** — use before `MPI_T_init_thread`, invalid
//!   index/handle/session, writes to read-only cvars;
//! * **scripted exchange** — a deterministic message pattern whose
//!   counter pvars must read *bitwise-exact* deltas on every config and
//!   transport, including the `rndv_threshold` cvar write visibly
//!   flipping the eager/rendezvous protocol choice.

use super::util::*;
use super::TestFn;
use crate::abi::constants as k;
use crate::abi::errors as ec;
use crate::api::{Dt, MpiAbi, OpName};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("mpit.enumerate_registry", enumerate_registry::<A>),
        ("mpit.error_paths", error_paths::<A>),
        ("mpit.scripted_exchange_counts", scripted_exchange_counts::<A>),
        ("mpit.coll_selection_counts", coll_selection_counts::<A>),
    ]
}

fn world_geometry<A: MpiAbi>() -> (i32, i32) {
    let (mut size, mut rank) = (0, 0);
    A::comm_size(A::comm_world(), &mut size);
    A::comm_rank(A::comm_world(), &mut rank);
    (size, rank)
}

/// The pvar registry in its fixed ABI order (mirrors
/// `core::obs::PVARS`; `tests/spec_sync.rs` pins the same list against
/// SPEC.md §11).
const PVAR_NAMES: [&str; 26] = [
    "sends_posted",
    "recvs_posted",
    "eager_msgs",
    "eager_bytes",
    "rndv_msgs",
    "rndv_bytes",
    "unexpected_depth",
    "unexpected_hwm",
    "posted_depth",
    "posted_hwm",
    "match_attempts",
    "wildcard_matches",
    "pending_send_depth",
    "pending_send_hwm",
    "rndv_inflight_peak",
    "sched_builds",
    "sched_reuses",
    "ranks_failed",
    "ops_failed_proc",
    "comms_revoked",
    "coll_sel_binomial",
    "coll_sel_ring",
    "coll_sel_recursive_doubling",
    "coll_sel_rabenseifner",
    "coll_sel_bruck",
    "coll_sel_pairwise",
];

/// Pvar indices used by the scripted-exchange test.
const PV_SENDS: i32 = 0;
const PV_RECVS: i32 = 1;
const PV_EAGER_MSGS: i32 = 2;
const PV_EAGER_BYTES: i32 = 3;
const PV_RNDV_MSGS: i32 = 4;
const PV_RNDV_BYTES: i32 = 5;
const PV_MATCH_ATTEMPTS: i32 = 10;
/// Selection counters (one per `COLL_ALGO_*` id, ABI order 20..=25).
/// `coll_sel_binomial` also counts the allgather gather+bcast baseline —
/// both are the binomial-tree builder.
const PV_COLL_SEL_BINOMIAL: i32 = 20;
const PV_COLL_SEL_RING: i32 = 21;
const PV_COLL_SEL_RECURSIVE_DOUBLING: i32 = 22;
const PV_COLL_SEL_RABENSEIFNER: i32 = 23;
const PV_COLL_SEL_BRUCK: i32 = 24;
const PV_COLL_SEL_PAIRWISE: i32 = 25;

const CV_RNDV_THRESHOLD: i32 = 0;
const CV_TRACE_ENABLED: i32 = 2;
const CV_COLL_ALLREDUCE_ALGO: i32 = 3;
const CV_COLL_ALLGATHER_ALGO: i32 = 4;
const CV_COLL_ALLTOALL_ALGO: i32 = 5;

/// Exact registry shape: counts, names, classes, scopes, binds.
fn enumerate_registry<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut provided = -1;
    check_rc!(A::t_init_thread(k::MPI_THREAD_SINGLE, &mut provided), "t_init_thread");
    check!(provided == k::MPI_THREAD_SINGLE, "provided level, got {provided}");

    let mut num = 0;
    check_rc!(A::t_cvar_get_num(&mut num), "t_cvar_get_num");
    check!(num == 6, "cvar count, got {num}");
    let expect_cvars = [
        ("rndv_threshold", k::MPI_T_SCOPE_LOCAL),
        ("flat_match", k::MPI_T_SCOPE_LOCAL),
        ("trace_enabled", k::MPI_T_SCOPE_READONLY),
        ("coll_allreduce_algo", k::MPI_T_SCOPE_LOCAL),
        ("coll_allgather_algo", k::MPI_T_SCOPE_LOCAL),
        ("coll_alltoall_algo", k::MPI_T_SCOPE_LOCAL),
    ];
    for (i, (want_name, want_scope)) in expect_cvars.iter().enumerate() {
        let mut name = String::new();
        let (mut verb, mut bind, mut scope) = (0, -1, -1);
        check_rc!(
            A::t_cvar_get_info(i as i32, &mut name, &mut verb, &mut bind, &mut scope),
            "t_cvar_get_info"
        );
        check!(name == *want_name, "cvar {i} name, got {name}");
        check!(scope == *want_scope, "cvar {name} scope, got {scope}");
        check!(bind == k::MPI_T_BIND_NO_OBJECT, "cvar {name} bind, got {bind}");
    }

    check_rc!(A::t_pvar_get_num(&mut num), "t_pvar_get_num");
    check!(num == PVAR_NAMES.len() as i32, "pvar count, got {num}");
    for (i, want_name) in PVAR_NAMES.iter().enumerate() {
        let mut name = String::new();
        let (mut verb, mut class, mut bind) = (0, -1, -1);
        check_rc!(
            A::t_pvar_get_info(i as i32, &mut name, &mut verb, &mut class, &mut bind),
            "t_pvar_get_info"
        );
        check!(name == *want_name, "pvar {i} name, got {name}");
        check!(bind == k::MPI_T_BIND_NO_OBJECT, "pvar {name} bind, got {bind}");
        let want_class = match i {
            6 | 8 | 12 | 17 => k::MPI_T_PVAR_CLASS_LEVEL,
            7 | 9 | 13 | 14 => k::MPI_T_PVAR_CLASS_HIGHWATERMARK,
            _ => k::MPI_T_PVAR_CLASS_COUNTER,
        };
        check!(class == want_class, "pvar {name} class, got {class}");
    }
    check_rc!(A::t_finalize(), "t_finalize");
    Ok(())
}

/// Every documented MPI_T failure mode, by error class.
fn error_paths<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let class = |rc: i32| A::err_class_of(rc);

    // Anything before MPI_T_init_thread (the tools interface has its
    // own lifetime, independent of MPI_Init).
    let mut num = 0;
    check!(
        class(A::t_cvar_get_num(&mut num)) == ec::MPI_T_ERR_NOT_INITIALIZED,
        "cvar_get_num before init"
    );
    let mut session = -1;
    check!(
        class(A::t_pvar_session_create(&mut session)) == ec::MPI_T_ERR_NOT_INITIALIZED,
        "session_create before init"
    );

    let mut provided = 0;
    check_rc!(A::t_init_thread(k::MPI_THREAD_SINGLE, &mut provided), "t_init_thread");

    // Out-of-range indices.
    let mut name = String::new();
    let (mut a, mut b, mut c) = (0, 0, 0);
    check!(
        class(A::t_cvar_get_info(99, &mut name, &mut a, &mut b, &mut c))
            == ec::MPI_T_ERR_INVALID_INDEX,
        "cvar_get_info bad index"
    );
    check!(
        class(A::t_pvar_get_info(-1, &mut name, &mut a, &mut b, &mut c))
            == ec::MPI_T_ERR_INVALID_INDEX,
        "pvar_get_info bad index"
    );
    let mut handle = -1;
    check!(
        class(A::t_cvar_handle_alloc(99, &mut handle)) == ec::MPI_T_ERR_INVALID_INDEX,
        "cvar_handle_alloc bad index"
    );

    // Never-allocated handles and sessions.
    let mut value = 0i64;
    check!(
        class(A::t_cvar_read(7, &mut value)) == ec::MPI_T_ERR_INVALID_HANDLE,
        "cvar_read bad handle"
    );
    check!(
        class(A::t_pvar_read(5, 0, &mut value)) == ec::MPI_T_ERR_INVALID_SESSION,
        "pvar_read bad session"
    );
    check_rc!(A::t_pvar_session_create(&mut session), "session_create");
    check!(
        class(A::t_pvar_read(session, 42, &mut value)) == ec::MPI_T_ERR_INVALID_HANDLE,
        "pvar_read bad handle"
    );

    // Writes rejected by scope and by value.
    check_rc!(A::t_cvar_handle_alloc(CV_TRACE_ENABLED, &mut handle), "alloc trace_enabled");
    check!(
        class(A::t_cvar_write(handle, 1)) == ec::MPI_T_ERR_CVAR_SET_NEVER,
        "write to read-only cvar"
    );
    check_rc!(A::t_cvar_handle_alloc(CV_RNDV_THRESHOLD, &mut handle), "alloc rndv_threshold");
    check!(
        class(A::t_cvar_write(handle, -5)) == ec::MPI_ERR_ARG,
        "negative cvar write"
    );
    // Force codes are a u8 surface: out-of-range writes are rejected
    // without touching the live selector.
    check_rc!(
        A::t_cvar_handle_alloc(CV_COLL_ALLREDUCE_ALGO, &mut handle),
        "alloc coll_allreduce_algo"
    );
    check!(
        class(A::t_cvar_write(handle, 256)) == ec::MPI_ERR_ARG,
        "force code above u8::MAX"
    );
    let mut force_now = -1i64;
    check_rc!(A::t_cvar_read(handle, &mut force_now), "coll cvar read");
    check!(force_now == 0, "rejected write must leave auto in place, got {force_now}");

    // After the last finalize the whole interface goes dormant again and
    // old handles/sessions are dead.
    check_rc!(A::t_finalize(), "t_finalize");
    check!(
        class(A::t_cvar_read(handle, &mut value)) == ec::MPI_T_ERR_NOT_INITIALIZED,
        "cvar_read after finalize"
    );
    Ok(())
}

/// Allocate-and-start one pvar handle in `session` (start re-baselines
/// counter-class pvars, so subsequent reads are deltas).
fn pvar_arm<A: MpiAbi>(session: i32, index: i32) -> Result<i32, String> {
    let mut handle = -1;
    let rc = A::t_pvar_handle_alloc(session, index, &mut handle);
    if rc != 0 {
        return Err(format!("pvar_handle_alloc({index}) rc {rc}"));
    }
    let rc = A::t_pvar_start(session, handle);
    if rc != 0 {
        return Err(format!("pvar_start({index}) rc {rc}"));
    }
    Ok(handle)
}

fn pvar_get<A: MpiAbi>(session: i32, handle: i32) -> Result<i64, String> {
    let mut v = -1i64;
    let rc = A::t_pvar_read(session, handle, &mut v);
    if rc != 0 {
        return Err(format!("pvar_read rc {rc}"));
    }
    Ok(v)
}

/// The deterministic scripted exchange: with `rndv_threshold` written
/// down to 1024 via its cvar, five 16-byte messages go eager and three
/// 4096-byte messages go rendezvous; written back above the message
/// size, the same 4096-byte message goes eager again. Counter deltas
/// are exact — the acceptance bar is bitwise-identical values on all
/// five configs × both transports.
fn scripted_exchange_counts<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Byte);
    let world = A::comm_world();

    let mut provided = 0;
    check_rc!(A::t_init_thread(k::MPI_THREAD_SINGLE, &mut provided), "t_init_thread");
    let mut session = -1;
    check_rc!(A::t_pvar_session_create(&mut session), "session_create");

    let result = (|| -> Result<(), String> {
        if me == 0 {
            let h_sends = pvar_arm::<A>(session, PV_SENDS)?;
            let h_emsgs = pvar_arm::<A>(session, PV_EAGER_MSGS)?;
            let h_ebytes = pvar_arm::<A>(session, PV_EAGER_BYTES)?;
            let h_rmsgs = pvar_arm::<A>(session, PV_RNDV_MSGS)?;
            let h_rbytes = pvar_arm::<A>(session, PV_RNDV_BYTES)?;

            let mut th = -1;
            check_rc!(A::t_cvar_handle_alloc(CV_RNDV_THRESHOLD, &mut th), "cvar alloc");
            let mut old = 0i64;
            check_rc!(A::t_cvar_read(th, &mut old), "cvar read");
            check_rc!(A::t_cvar_write(th, 1024), "cvar write 1024");
            let mut now = 0i64;
            check_rc!(A::t_cvar_read(th, &mut now), "cvar re-read");
            check!(now == 1024, "cvar write round-trip, got {now}");

            let small = [7u8; 16];
            let big = [9u8; 4096];
            for i in 0..5 {
                check_rc!(A::send(slice_ptr(&small), 16, dt, 1, 100 + i, world), "small send");
            }
            for j in 0..3 {
                check_rc!(A::send(slice_ptr(&big), 4096, dt, 1, 200 + j, world), "big send");
            }
            check!(pvar_get::<A>(session, h_sends)? == 8, "sends_posted != 8");
            check!(pvar_get::<A>(session, h_emsgs)? == 5, "eager_msgs != 5");
            check!(pvar_get::<A>(session, h_ebytes)? == 80, "eager_bytes != 80");
            check!(pvar_get::<A>(session, h_rmsgs)? == 3, "rndv_msgs != 3");
            check!(pvar_get::<A>(session, h_rbytes)? == 12288, "rndv_bytes != 12288");

            // Raise the threshold back over the message size: the very
            // same send must now take the eager path — the cvar write
            // observably flips the protocol.
            check_rc!(A::t_cvar_write(th, 8192), "cvar write 8192");
            check_rc!(A::send(slice_ptr(&big), 4096, dt, 1, 300, world), "flip send");
            check!(pvar_get::<A>(session, h_sends)? == 9, "sends_posted != 9");
            check!(pvar_get::<A>(session, h_emsgs)? == 6, "eager_msgs != 6");
            check!(pvar_get::<A>(session, h_ebytes)? == 4176, "eager_bytes != 4176");
            check!(pvar_get::<A>(session, h_rmsgs)? == 3, "rndv_msgs moved");
            check!(pvar_get::<A>(session, h_rbytes)? == 12288, "rndv_bytes moved");

            check_rc!(A::t_cvar_write(th, old), "cvar restore");
        } else if me == 1 {
            let h_recvs = pvar_arm::<A>(session, PV_RECVS)?;
            let h_attempts = pvar_arm::<A>(session, PV_MATCH_ATTEMPTS)?;

            let mut small = [0u8; 16];
            let mut big = [0u8; 4096];
            let mut st = A::status_empty();
            for i in 0..5 {
                check_rc!(
                    A::recv(slice_ptr_mut(&mut small), 16, dt, 0, 100 + i, world, &mut st),
                    "small recv"
                );
                check!(small[0] == 7, "small payload");
            }
            for j in 0..3 {
                check_rc!(
                    A::recv(slice_ptr_mut(&mut big), 4096, dt, 0, 200 + j, world, &mut st),
                    "big recv"
                );
                check!(big[4095] == 9, "big payload");
            }
            check_rc!(
                A::recv(slice_ptr_mut(&mut big), 4096, dt, 0, 300, world, &mut st),
                "flip recv"
            );
            check!(pvar_get::<A>(session, h_recvs)? == 9, "recvs_posted != 9");
            // Timing-dependent (probes and unexpected arrivals add
            // attempts), so only a floor is portable.
            check!(pvar_get::<A>(session, h_attempts)? >= 9, "match_attempts floor");
        }
        Ok(())
    })();

    check_rc!(A::t_finalize(), "t_finalize");
    result
}

/// The PR-10 selection layer, observed end to end through MPI_T: cvar
/// writes retarget the live selector, and the per-algorithm selection
/// counters (pvar indices 20..=25) tick **exactly once per schedule
/// build** — forced and auto picks alike. Every rank runs the identical
/// script (the collectives are collective; every rank builds its own
/// schedule), so the deltas are exact on every rank, every config, and
/// both transports. Counts are distinct per call so no schedule is
/// reused from the cache (reuse deliberately does not re-count).
fn coll_selection_counts<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, _me) = world_geometry::<A>();
    if n < 3 {
        // n <= 2 pins allreduce to binomial before the selector runs.
        return Ok(());
    }
    let world = A::comm_world();
    let dt = A::datatype(Dt::Int);
    let op = A::op(OpName::Sum);

    let mut provided = 0;
    check_rc!(A::t_init_thread(k::MPI_THREAD_SINGLE, &mut provided), "t_init_thread");
    let mut session = -1;
    check_rc!(A::t_pvar_session_create(&mut session), "session_create");

    let result = (|| -> Result<(), String> {
        let h_bin = pvar_arm::<A>(session, PV_COLL_SEL_BINOMIAL)?;
        let h_ring = pvar_arm::<A>(session, PV_COLL_SEL_RING)?;
        let h_rd = pvar_arm::<A>(session, PV_COLL_SEL_RECURSIVE_DOUBLING)?;
        let h_rab = pvar_arm::<A>(session, PV_COLL_SEL_RABENSEIFNER)?;
        let h_bruck = pvar_arm::<A>(session, PV_COLL_SEL_BRUCK)?;
        let h_pair = pvar_arm::<A>(session, PV_COLL_SEL_PAIRWISE)?;

        let (mut ch_ar, mut ch_ag, mut ch_aa) = (-1, -1, -1);
        check_rc!(A::t_cvar_handle_alloc(CV_COLL_ALLREDUCE_ALGO, &mut ch_ar), "alloc ar");
        check_rc!(A::t_cvar_handle_alloc(CV_COLL_ALLGATHER_ALGO, &mut ch_ag), "alloc ag");
        check_rc!(A::t_cvar_handle_alloc(CV_COLL_ALLTOALL_ALGO, &mut ch_aa), "alloc aa");
        for (name, h) in [("ar", ch_ar), ("ag", ch_ag), ("aa", ch_aa)] {
            let mut v = -1i64;
            check_rc!(A::t_cvar_read(h, &mut v), "initial read");
            check!(v == 0, "{name} default must be auto, got {v}");
        }

        // Distinct counts per call: no two collectives share a cached
        // schedule, so builds (and selection ticks) are 1:1 with calls.
        let mut next_count = 4i32;
        let mut allreduce = |force: i64| -> Result<(), String> {
            check_rc!(A::t_cvar_write(ch_ar, force), "cvar write ar");
            let count = next_count;
            next_count += 1;
            let send = vec![1i32; count as usize];
            let mut recv = vec![0i32; count as usize];
            check_rc!(
                A::allreduce(
                    slice_ptr(&send),
                    slice_ptr_mut(&mut recv),
                    count,
                    dt,
                    op,
                    world
                ),
                "allreduce"
            );
            check!(recv[0] == n, "allreduce value, got {}", recv[0]);
            Ok(())
        };
        allreduce(2)?; // forced ring
        allreduce(3)?; // forced recursive doubling
        allreduce(3)?; // forced recursive doubling, new count = new build
        allreduce(4)?; // forced Rabenseifner
        allreduce(1)?; // forced binomial baseline
        allreduce(0)?; // auto: tens of bytes -> recursive doubling band
        check!(pvar_get::<A>(session, h_ring)? == 1, "ring after allreduce block");
        check!(pvar_get::<A>(session, h_rd)? == 3, "rd after allreduce block");
        check!(pvar_get::<A>(session, h_rab)? == 1, "rabenseifner after allreduce block");
        check!(pvar_get::<A>(session, h_bin)? == 1, "binomial after allreduce block");

        let mut allgather = |force: i64| -> Result<(), String> {
            check_rc!(A::t_cvar_write(ch_ag, force), "cvar write ag");
            let count = next_count;
            next_count += 1;
            let send = vec![7i32; count as usize];
            let mut recv = vec![0i32; count as usize * n as usize];
            check_rc!(
                A::allgather(
                    slice_ptr(&send),
                    count,
                    dt,
                    slice_ptr_mut(&mut recv),
                    count,
                    dt,
                    world
                ),
                "allgather"
            );
            check!(recv[0] == 7, "allgather value, got {}", recv[0]);
            Ok(())
        };
        allgather(1)?; // forced gather+bcast — the binomial-tree builder
        allgather(2)?; // forced ring
        allgather(0)?; // auto: tiny total at n <= 8 -> ring band
        check!(pvar_get::<A>(session, h_bin)? == 2, "binomial after allgather block");
        check!(pvar_get::<A>(session, h_ring)? == 3, "ring after allgather block");

        let mut alltoall = |force: i64| -> Result<(), String> {
            check_rc!(A::t_cvar_write(ch_aa, force), "cvar write aa");
            let count = next_count;
            next_count += 1;
            let send = vec![9i32; count as usize * n as usize];
            let mut recv = vec![0i32; count as usize * n as usize];
            check_rc!(
                A::alltoall(
                    slice_ptr(&send),
                    count,
                    dt,
                    slice_ptr_mut(&mut recv),
                    count,
                    dt,
                    world
                ),
                "alltoall"
            );
            check!(recv[0] == 9, "alltoall value, got {}", recv[0]);
            Ok(())
        };
        alltoall(2)?; // forced Bruck
        alltoall(1)?; // forced pairwise
        alltoall(0)?; // auto: small blocks at n <= 7 -> pairwise band
        check!(pvar_get::<A>(session, h_bruck)? == 1, "bruck after alltoall block");
        check!(pvar_get::<A>(session, h_pair)? == 2, "pairwise after alltoall block");

        // Full ledger: nothing else moved.
        check!(pvar_get::<A>(session, h_bin)? == 2, "final binomial");
        check!(pvar_get::<A>(session, h_ring)? == 3, "final ring");
        check!(pvar_get::<A>(session, h_rd)? == 3, "final recursive_doubling");
        check!(pvar_get::<A>(session, h_rab)? == 1, "final rabenseifner");
        check!(pvar_get::<A>(session, h_bruck)? == 1, "final bruck");
        check!(pvar_get::<A>(session, h_pair)? == 2, "final pairwise");

        // Restore auto everywhere (later registry entries and the
        // verdict-combining allreduce must see the default selector).
        check_rc!(A::t_cvar_write(ch_ar, 0), "restore ar");
        check_rc!(A::t_cvar_write(ch_ag, 0), "restore ag");
        check_rc!(A::t_cvar_write(ch_aa, 0), "restore aa");
        Ok(())
    })();

    check_rc!(A::t_finalize(), "t_finalize");
    result
}
