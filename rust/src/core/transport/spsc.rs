//! Lock-free single-producer single-consumer ring queue.
//!
//! This is the "UCX shared-memory" analogue of Table 1: the fast transport.
//! One queue exists per ordered rank pair `(sender, receiver)`; the sender
//! thread is the only producer and the receiver thread the only consumer,
//! so a classic Lamport ring with acquire/release indices suffices — no
//! CAS, no locks on the message path.

use core::cell::UnsafeCell;
use core::mem::MaybeUninit;
use core::sync::atomic::{AtomicUsize, Ordering};
use crossbeam_utils::CachePadded;

/// Fixed-capacity SPSC ring. Capacity is rounded up to a power of two.
pub struct Spsc<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: usize,
    /// Next slot to read (owned by consumer; read by producer).
    head: CachePadded<AtomicUsize>,
    /// Next slot to write (owned by producer; read by consumer).
    tail: CachePadded<AtomicUsize>,
}

// Safety: only one thread pushes and one thread pops; the atomics order
// access to the slots.
unsafe impl<T: Send> Send for Spsc<T> {}
unsafe impl<T: Send> Sync for Spsc<T> {}

impl<T> Spsc<T> {
    /// Create with at least `capacity` slots.
    pub fn new(capacity: usize) -> Spsc<T> {
        let cap = capacity.next_power_of_two().max(2);
        let buf = (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        Spsc {
            buf,
            mask: cap - 1,
            head: CachePadded::new(AtomicUsize::new(0)),
            tail: CachePadded::new(AtomicUsize::new(0)),
        }
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Producer-side: append `v`, or return it if the ring is full.
    ///
    /// # Safety contract (by construction, not types)
    /// Must only be called from the unique producer thread.
    #[inline]
    pub fn push(&self, v: T) -> Result<(), T> {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) > self.mask {
            return Err(v); // full
        }
        unsafe {
            (*self.buf[tail & self.mask].get()).write(v);
        }
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Consumer-side: pop the oldest element, if any.
    ///
    /// Must only be called from the unique consumer thread.
    #[inline]
    pub fn pop(&self) -> Option<T> {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head == tail {
            return None; // empty
        }
        let v = unsafe { (*self.buf[head & self.mask].get()).assume_init_read() };
        self.head.store(head.wrapping_add(1), Ordering::Release);
        Some(v)
    }

    /// Consumer-side: `true` if no messages are waiting. Cheap peek used by
    /// the progress loop to skip empty peers.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Relaxed) == self.tail.load(Ordering::Acquire)
    }
}

impl<T> Drop for Spsc<T> {
    fn drop(&mut self) {
        // Drain remaining elements so their destructors run.
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let q = Spsc::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        let q: Spsc<u8> = Spsc::new(5);
        assert_eq!(q.capacity(), 8);
        let q: Spsc<u8> = Spsc::new(8);
        assert_eq!(q.capacity(), 8);
    }

    #[test]
    fn full_rejects_and_returns_value() {
        let q = Spsc::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.pop(), Some(1));
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn wraparound_many_times() {
        let q = Spsc::new(4);
        for round in 0u64..100 {
            for i in 0..3 {
                q.push(round * 10 + i).unwrap();
            }
            for i in 0..3 {
                assert_eq!(q.pop(), Some(round * 10 + i));
            }
        }
    }

    #[test]
    fn drop_drains_elements() {
        // Vec payloads must be freed when the queue is dropped non-empty.
        let q = Spsc::new(8);
        q.push(vec![1u8; 100]).unwrap();
        q.push(vec![2u8; 100]).unwrap();
        drop(q); // must not leak (checked under miri/asan in CI-like runs)
    }

    #[test]
    fn two_thread_stress() {
        let q = std::sync::Arc::new(Spsc::new(16));
        let p = q.clone();
        let n = 10_000u64;
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                let mut v = i;
                loop {
                    match p.push(v) {
                        Ok(()) => break,
                        Err(back) => {
                            v = back;
                            std::thread::yield_now();
                        }
                    }
                }
            }
        });
        let mut expected = 0u64;
        while expected < n {
            if let Some(v) = q.pop() {
                assert_eq!(v, expected);
                expected += 1;
            } else {
                std::thread::yield_now();
            }
        }
        producer.join().unwrap();
        assert!(q.is_empty());
    }
}
