//! Mukautuva handle-conversion round-trip properties, over every handle
//! kind (including `Win`): wrap→backend→wrap identity for runtime
//! handles (the word union must be lossless), null-handle mapping in
//! both directions, predefined-constant table symmetry, and the §5.4
//! integer-constant translation (lock types, assertion bitmasks).

use mpi_abi::abi::constants as std_k;
use mpi_abi::abi::handles as std_h;
use mpi_abi::abi::huffman::HUFFMAN_MAX;
use mpi_abi::api::MpiAbi;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::muk::convert::*;
use mpi_abi::muk::word::AsWord;

/// Deterministic word stream above the zero page. For the MPICH backend
/// the union member is an `int`, so words stay in u32 range with the
/// KIND_DIRECT bit patterns real MPICH user handles carry.
fn sample_words(kind_bits: i32) -> Vec<usize> {
    let mut x: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut out = Vec::new();
    for _ in 0..64 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let payload = (x >> 40) as i32 & ((1 << 26) - 1);
        let w = (mpi_abi::impls::mpich::KIND_DIRECT | kind_bits | payload) as u32 as usize;
        assert!(w > HUFFMAN_MAX, "sample must clear the zero page");
        out.push(w);
    }
    out
}

/// One kind's property: every sampled runtime word survives
/// muk→backend→muk bit-identically.
macro_rules! roundtrip_kind {
    ($backend:ty, $to_impl:ident, $to_muk:ident, $kind_bits:expr) => {
        for w in sample_words($kind_bits) {
            let b = $to_impl::<$backend>(w);
            assert_eq!($to_muk::<$backend>(b), w, "{} word {w:#x}", stringify!($to_impl));
        }
    };
}

#[test]
fn runtime_handles_roundtrip_mpich() {
    use mpi_abi::impls::mpich as m;
    roundtrip_kind!(MpichAbi, comm_to_impl, comm_to_muk, m::T_COMM);
    roundtrip_kind!(MpichAbi, dt_to_impl, dt_to_muk, m::T_DATATYPE);
    roundtrip_kind!(MpichAbi, req_to_impl, req_to_muk, m::T_REQUEST);
    roundtrip_kind!(MpichAbi, win_to_impl, win_to_muk, m::T_WIN);
    roundtrip_kind!(MpichAbi, session_to_impl, session_to_muk, m::T_SESSION);
    roundtrip_kind!(MpichAbi, errh_to_impl, errh_to_muk, m::T_ERRHANDLER);
}

#[test]
fn runtime_handles_roundtrip_ompi() {
    // Pointer-handle backend. Comm/request/win/errhandler conversion
    // only *compares* addresses against the predefined descriptors, so
    // synthetic words round-trip without ever being dereferenced.
    use mpi_abi::impls::mpich as m;
    roundtrip_kind!(OmpiAbi, comm_to_impl, comm_to_muk, m::T_COMM);
    roundtrip_kind!(OmpiAbi, req_to_impl, req_to_muk, m::T_REQUEST);
    roundtrip_kind!(OmpiAbi, win_to_impl, win_to_muk, m::T_WIN);
    roundtrip_kind!(OmpiAbi, session_to_impl, session_to_muk, m::T_SESSION);
    roundtrip_kind!(OmpiAbi, errh_to_impl, errh_to_muk, m::T_ERRHANDLER);
}

#[test]
fn runtime_datatype_handles_roundtrip_ompi() {
    // Datatype conversion *dereferences* the descriptor (the
    // predefined-reverse check reads its engine id), so the samples must
    // be genuine Open-MPI-style descriptors — exactly what the backend
    // would hand out for derived types.
    use mpi_abi::impls::repr::Repr;
    for k in 0..32u32 {
        let h = mpi_abi::impls::ompi::OmpiRepr::dt_h(mpi_abi::core::DtId(1000 + k));
        let w = h.to_word();
        assert!(w > HUFFMAN_MAX);
        let b = dt_to_impl::<OmpiAbi>(w);
        assert_eq!(dt_to_muk::<OmpiAbi>(b), w, "ompi derived dt {w:#x}");
    }
}

/// Null handles map constant↔constant in both directions, for both
/// backends, for every kind that has a null conversion.
#[test]
fn null_handles_map_both_ways() {
    fn check<A: MukBackend>() {
        assert_eq!(comm_to_impl::<A>(std_h::MPI_COMM_NULL), A::comm_null());
        assert_eq!(comm_to_muk::<A>(A::comm_null()), std_h::MPI_COMM_NULL);
        assert_eq!(req_to_impl::<A>(std_h::MPI_REQUEST_NULL), A::request_null());
        assert_eq!(req_to_muk::<A>(A::request_null()), std_h::MPI_REQUEST_NULL);
        assert_eq!(win_to_impl::<A>(std_h::MPI_WIN_NULL), A::win_null());
        assert_eq!(win_to_muk::<A>(A::win_null()), std_h::MPI_WIN_NULL);
        assert_eq!(session_to_impl::<A>(std_h::MPI_SESSION_NULL), A::session_null());
        assert_eq!(session_to_muk::<A>(A::session_null()), std_h::MPI_SESSION_NULL);
        // Info lacks Debug in the ABI trait; compare without assert_eq.
        assert!(info_to_impl::<A>(std_h::MPI_INFO_NULL) == A::info_null());
    }
    check::<MpichAbi>();
    check::<OmpiAbi>();
}

/// Every predefined datatype and op constant translates to the backend
/// and back to the same zero-page word.
#[test]
fn predefined_constants_roundtrip() {
    fn check<A: MukBackend>(name: &str) {
        for &(_, c) in mpi_abi::abi::datatypes::PREDEFINED_DATATYPES {
            if c == mpi_abi::abi::datatypes::MPI_DATATYPE_NULL {
                continue;
            }
            let b = dt_to_impl::<A>(c);
            assert_eq!(dt_to_muk::<A>(b), c, "{name} dt {c:#x}");
        }
        for &(_, c) in mpi_abi::abi::ops::PREDEFINED_OPS {
            if c == mpi_abi::abi::ops::MPI_OP_NULL {
                continue;
            }
            let b = op_to_impl::<A>(c);
            assert_eq!(A::predef_op_rev(b), Some(c), "{name} op {c:#x}");
        }
    }
    check::<MpichAbi>("mpich");
    check::<OmpiAbi>("ompi");
}

/// §5.4 integer constants translate by value: lock types hit MPICH's
/// historical 234/235, and assertion bitmasks re-encode into Open MPI's
/// dense numbering bit by bit.
#[test]
fn lock_and_assert_constants_translate() {
    assert_eq!(lock_type_to_impl::<MpichAbi>(std_k::MPI_LOCK_EXCLUSIVE), 234);
    assert_eq!(lock_type_to_impl::<MpichAbi>(std_k::MPI_LOCK_SHARED), 235);
    assert_eq!(
        lock_type_to_impl::<OmpiAbi>(std_k::MPI_LOCK_EXCLUSIVE),
        std_k::MPI_LOCK_EXCLUSIVE
    );

    // MPICH shares the standard ABI's mode values: identity.
    let all = std_k::MPI_MODE_NOCHECK
        | std_k::MPI_MODE_NOSTORE
        | std_k::MPI_MODE_NOPUT
        | std_k::MPI_MODE_NOPRECEDE
        | std_k::MPI_MODE_NOSUCCEED;
    assert_eq!(assert_to_impl::<MpichAbi>(all), all);
    assert_eq!(assert_to_impl::<MpichAbi>(0), 0);

    // Open MPI renumbers the family; each bit maps individually.
    use mpi_abi::impls::ompi as o;
    assert_eq!(assert_to_impl::<OmpiAbi>(std_k::MPI_MODE_NOCHECK), o::MPI_MODE_NOCHECK);
    assert_eq!(assert_to_impl::<OmpiAbi>(std_k::MPI_MODE_NOSUCCEED), o::MPI_MODE_NOSUCCEED);
    assert_eq!(
        assert_to_impl::<OmpiAbi>(std_k::MPI_MODE_NOCHECK | std_k::MPI_MODE_NOPUT),
        o::MPI_MODE_NOCHECK | o::MPI_MODE_NOPUT
    );
    assert_eq!(assert_to_impl::<OmpiAbi>(all),
        o::MPI_MODE_NOCHECK | o::MPI_MODE_NOSTORE | o::MPI_MODE_NOPUT | o::MPI_MODE_NOPRECEDE
            | o::MPI_MODE_NOSUCCEED);
}

/// The backend `Win` handle types ride the word union losslessly
/// (pointer-width preservation, sign bit of MPICH int handles included).
#[test]
fn win_word_union_preserves_bits() {
    let mpich_win: i32 = mpi_abi::impls::mpich::KIND_DIRECT | mpi_abi::impls::mpich::T_WIN | 7;
    assert_eq!(<i32 as AsWord>::from_word(mpich_win.to_word()), mpich_win);
    let desc = Box::leak(Box::new(0u64));
    let ompi_win = mpi_abi::impls::ompi::OmpiWin(
        desc as *const u64 as *const mpi_abi::impls::ompi::Desc,
    );
    assert_eq!(mpi_abi::impls::ompi::OmpiWin::from_word(ompi_win.to_word()), ompi_win);
}
