//! The MPI engine substrate.
//!
//! Everything below the ABI surfaces: a complete message-passing engine
//! (the role MPICH's CH4 / Open MPI's OB1 play under their `mpi.h`s).
//! Both implementation ABIs ([`crate::impls`]) and the native standard-ABI
//! build ([`crate::native_abi`]) are thin handle-conversion shims over the
//! functions in [`engine`].
//!
//! Object identity: the engine names objects with dense per-rank ids
//! ([`slab::Slab`] indices). ABIs map their wire representation (an `i32`
//! with encoded bits, a pointer to a descriptor, a zero-page Huffman word)
//! to these ids at the boundary — that conversion *is* the subject of the
//! paper.

#![warn(missing_docs)]

pub mod attr;
pub mod collectives;
pub mod comm;
pub mod datatype;
pub mod engine;
pub mod errh;
pub mod group;
pub mod info;
pub mod match_index;
pub mod obs;
pub mod op;
pub mod request;
pub mod rma;
pub mod session;
pub mod slab;
pub mod transport;
pub mod world;

use crate::abi::errors as ec;

/// Engine-level error: canonical (standard-ABI-numbered) error class.
/// Implementations re-encode this into their own error-code spaces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MpiError {
    /// Canonical error class (standard-ABI numbering, `abi::errors`).
    pub class: i32,
}

impl MpiError {
    /// Wrap a canonical error class.
    pub const fn new(class: i32) -> MpiError {
        MpiError { class }
    }
    /// Human-readable description of the class.
    pub fn message(self) -> &'static str {
        ec::error_string(self.class)
    }
}

impl std::fmt::Display for MpiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} ({})",
            ec::error_class_name(self.class).unwrap_or("MPI_ERR_?"),
            self.message()
        )
    }
}

impl std::error::Error for MpiError {}

/// Engine result type.
pub type RC<T = ()> = Result<T, MpiError>;

macro_rules! err {
    ($class:ident) => {
        crate::core::MpiError::new(crate::abi::errors::$class)
    };
}
pub(crate) use err;

/// Dense engine object ids (indices into per-rank slabs).
macro_rules! engine_id {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
        pub struct $name(pub u32);
    };
}

engine_id!(
    /// Communicator id.
    CommId
);
engine_id!(
    /// Group id.
    GroupId
);
engine_id!(
    /// Datatype id.
    DtId
);
engine_id!(
    /// Reduction-op id.
    OpId
);
engine_id!(
    /// Request id.
    ReqId
);
engine_id!(
    /// Error-handler id.
    ErrhId
);
engine_id!(
    /// Info-object id.
    InfoId
);
engine_id!(
    /// RMA window id.
    WinId
);
engine_id!(
    /// MPI-4 session id.
    SessionId
);

/// Pre-reserved ids for predefined objects: every rank's tables are
/// initialized so these indices hold the predefined objects, letting
/// ABI constants convert to ids with pure arithmetic.
pub mod reserved {
    use super::*;
    /// `MPI_COMM_WORLD`'s engine id.
    pub const COMM_WORLD: CommId = CommId(0);
    /// `MPI_COMM_SELF`'s engine id.
    pub const COMM_SELF: CommId = CommId(1);
    /// The hidden world-spanning bootstrap comm used by
    /// `MPI_Comm_create_from_group` to agree on context planes without
    /// a parent communicator (see [`crate::core::session`]). Never
    /// exposed through any ABI.
    pub const COMM_BOOTSTRAP: CommId = CommId(2);
    /// `MPI_GROUP_EMPTY`'s engine id.
    pub const GROUP_EMPTY: GroupId = GroupId(0);
    /// The world group's engine id.
    pub const GROUP_WORLD: GroupId = GroupId(1);
    /// The self group's engine id.
    pub const GROUP_SELF: GroupId = GroupId(2);
    /// `MPI_ERRORS_ARE_FATAL`'s engine id.
    pub const ERRH_ARE_FATAL: ErrhId = ErrhId(0);
    /// `MPI_ERRORS_RETURN`'s engine id.
    pub const ERRH_RETURN: ErrhId = ErrhId(1);
    /// `MPI_ERRORS_ABORT`'s engine id.
    pub const ERRH_ABORT: ErrhId = ErrhId(2);
    /// `MPI_INFO_ENV`'s engine id.
    pub const INFO_ENV: InfoId = InfoId(0);
    /// Builtin ops occupy op ids 0..NUM_BUILTIN_OPS in A.1 order.
    pub const NUM_BUILTIN_OPS: u32 = 15;
    /// Builtin datatypes occupy dt ids 0..len(PREDEFINED_DATATYPES) in
    /// table order (id 0 = MPI_DATATYPE_NULL's slot, never dereferenced).
    pub const NUM_BUILTIN_DTYPES: u32 = crate::abi::datatypes::PREDEFINED_DATATYPES.len() as u32;
}
