//! E4: the ABI-agnostic test suite, run against all five configurations
//! (the paper's "MUK passes the MPICH test suite against both backends"
//! plus the two native ABIs and the native standard-ABI build).

use mpi_abi::api::MpiAbi;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;
use mpi_abi::testsuite;

fn run_suite<A: MpiAbi>(ranks: usize) {
    let reports = run_job_ok(JobSpec::new(ranks), |rank| {
        assert_eq!(A::init(), 0, "{} init", A::NAME);
        let results = testsuite::run_all::<A>(rank);
        let report = testsuite::report(A::NAME, &results);
        let failed: Vec<_> = results.iter().filter(|r| !r.passed).collect();
        assert_eq!(A::finalize(), 0, "{} finalize", A::NAME);
        (report, failed.len())
    });
    let (report, failures) = &reports[0];
    if *failures > 0 {
        panic!("{report}");
    }
}

#[test]
fn suite_mpich_native() {
    run_suite::<MpichAbi>(4);
}

#[test]
fn suite_ompi_native() {
    run_suite::<OmpiAbi>(4);
}

#[test]
fn suite_muk_over_mpich() {
    run_suite::<MukMpich>(4);
}

#[test]
fn suite_muk_over_ompi() {
    run_suite::<MukOmpi>(4);
}

#[test]
fn suite_native_standard_abi() {
    run_suite::<NativeAbi>(4);
}

#[test]
fn suite_works_on_two_and_three_ranks() {
    run_suite::<NativeAbi>(2);
    run_suite::<MukMpich>(3);
}
