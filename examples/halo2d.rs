//! 2-D Jacobi halo exchange across ABIs: the stencil result must be
//! bit-identical whichever MPI library carries the halos — and whichever
//! exchange mode (per-sweep sendrecv, persistent start/wait, or
//! fence-synchronized RMA puts) drives it.
//!
//! ```bash
//! cargo run --release --example halo2d [ranks] [n] [iters]
//! ```

use mpi_abi::api::MpiAbi;
use mpi_abi::apps::halo::{jacobi, HaloMode, HaloParams};
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::muk::MukMpich;
use mpi_abi::native_abi::NativeAbi;

fn run<A: MpiAbi>(ranks: usize, n: usize, iters: usize, mode: HaloMode) -> f64 {
    let out = run_job_ok(JobSpec::new(ranks), move |_| {
        A::init();
        let (_, global) = jacobi::<A>(HaloParams { n, iters, mode });
        A::finalize();
        global
    });
    out[0]
}

fn main() {
    let mut args = std::env::args().skip(1);
    let ranks: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(3);
    let n: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(96);
    let iters: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(50);
    println!("2-D Jacobi: {n}x{n} grid, {ranks} ranks, {iters} sweeps");

    let a = run::<NativeAbi>(ranks, n, iters, HaloMode::Sendrecv);
    println!("  native std ABI : residual {a:.12}");
    let b = run::<MpichAbi>(ranks, n, iters, HaloMode::Sendrecv);
    println!("  mpich-like ABI : residual {b:.12}");
    let c = run::<OmpiAbi>(ranks, n, iters, HaloMode::Sendrecv);
    println!("  ompi-like ABI  : residual {c:.12}");
    let d = run::<MukMpich>(ranks, n, iters, HaloMode::Sendrecv);
    println!("  muk(mpich)     : residual {d:.12}");
    assert!(a == b && b == c && c == d, "results must be ABI-independent");
    assert!(a > 0.0, "heat must have diffused from the boundary");

    // Persistent halo exchange (MPI-4 Send_init/Recv_init + Startall):
    // same halos, init-once/start-N — the result must not change.
    let e = run::<NativeAbi>(ranks, n, iters, HaloMode::Persistent);
    println!("  abi, persistent: residual {e:.12}");
    let f = run::<MukMpich>(ranks, n, iters, HaloMode::Persistent);
    println!("  muk, persistent: residual {f:.12}");
    assert!(a == e && e == f, "persistent exchange must be bit-identical");

    // RMA halo exchange (MPI_Put + MPI_Win_fence): one-sided ghost-row
    // updates must produce the same bits as the two-sided modes.
    let g = run::<NativeAbi>(ranks, n, iters, HaloMode::Rma);
    println!("  abi, rma       : residual {g:.12}");
    let h = run::<MukMpich>(ranks, n, iters, HaloMode::Rma);
    println!("  muk, rma       : residual {h:.12}");
    assert!(a == g && g == h, "RMA exchange must be bit-identical");
    println!("bit-identical across all libraries and exchange modes ✓");
}
