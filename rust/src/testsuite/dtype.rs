//! Datatype tests: sizes, extents, derived constructors, and
//! heterogeneous transfers.

use super::util::*;
use super::TestFn;
use crate::api::{Dt, MpiAbi};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("dtype.builtin_sizes", builtin_sizes::<A>),
        ("dtype.extents", extents::<A>),
        ("dtype.contiguous", contiguous::<A>),
        ("dtype.vector_column_exchange", vector_column_exchange::<A>),
        ("dtype.struct_layout", struct_layout::<A>),
        ("dtype.dup_and_free", dup_and_free::<A>),
        ("dtype.get_count_undefined", get_count_undefined::<A>),
        ("dtype.get_count_derived", get_count_derived::<A>),
        ("dtype.get_elements_partial", get_elements_partial::<A>),
    ]
}

fn builtin_sizes<A: MpiAbi>(_r: usize) -> Result<(), String> {
    // The §6.1 semantic: every ABI must report identical sizes, whatever
    // its lookup mechanism (handle bits, descriptor deref, Huffman).
    let want: &[(Dt, i32)] = &[
        (Dt::Byte, 1),
        (Dt::Char, 1),
        (Dt::Short, 2),
        (Dt::UInt16, 2),
        (Dt::Int, 4),
        (Dt::Int32, 4),
        (Dt::Float, 4),
        (Dt::Double, 8),
        (Dt::Int64, 8),
        (Dt::UInt64, 8),
        (Dt::Aint, core::mem::size_of::<usize>() as i32),
        (Dt::FloatInt, 8),
        (Dt::TwoInt, 8),
    ];
    for &(d, s) in want {
        let mut out = 0;
        check_rc!(A::type_size(A::datatype(d), &mut out), "Type_size");
        check!(out == s, "{d:?}: size {out}, want {s}");
    }
    Ok(())
}

fn extents<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (mut lb, mut extent) = (0isize, 0isize);
    check_rc!(A::type_get_extent(A::datatype(Dt::Double), &mut lb, &mut extent), "extent");
    check!(lb == 0 && extent == 8, "double: lb {lb}, extent {extent}");
    Ok(())
}

fn contiguous<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut t = A::datatype(Dt::Byte);
    check_rc!(A::type_contiguous(5, A::datatype(Dt::Int), &mut t), "contiguous");
    check_rc!(A::type_commit(&mut t), "commit");
    let mut size = 0;
    check_rc!(A::type_size(t, &mut size), "size");
    check!(size == 20, "5 ints = 20 bytes, got {size}");
    let (mut lb, mut extent) = (0isize, 0isize);
    check_rc!(A::type_get_extent(t, &mut lb, &mut extent), "extent");
    check!(extent == 20, "extent 20, got {extent}");
    check_rc!(A::type_free(&mut t), "free");
    Ok(())
}

fn vector_column_exchange<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    if n < 2 {
        return Ok(());
    }
    // Column of a 4x4 row-major matrix.
    let mut col_t = A::datatype(Dt::Byte);
    check_rc!(A::type_vector(4, 1, 4, A::datatype(Dt::Int), &mut col_t), "vector");
    check_rc!(A::type_commit(&mut col_t), "commit");
    let mut size = 0;
    check_rc!(A::type_size(col_t, &mut size), "size");
    check!(size == 16, "vector packs 4 ints");
    if me == 0 {
        let m: Vec<i32> = (0..16).collect();
        check_rc!(A::send(slice_ptr(&m), 1, col_t, 1, 4, A::comm_world()), "send column");
    } else if me == 1 {
        let mut col = [0i32; 4];
        let mut st = A::status_empty();
        check_rc!(
            A::recv(slice_ptr_mut(&mut col), 4, A::datatype(Dt::Int), 0, 4, A::comm_world(),
                &mut st),
            "recv"
        );
        check!(col == [0, 4, 8, 12], "column data, got {col:?}");
        // And scatter a contiguous buffer back *into* a column.
        let send = [100i32, 101, 102, 103];
        check_rc!(A::send(slice_ptr(&send), 4, A::datatype(Dt::Int), 0, 5, A::comm_world()),
            "send back");
    }
    if me == 0 {
        let mut m = [0i32; 16];
        let mut st = A::status_empty();
        check_rc!(A::recv(slice_ptr_mut(&mut m), 1, col_t, 1, 5, A::comm_world(), &mut st),
            "recv into column");
        check!(m[0] == 100 && m[4] == 101 && m[8] == 102 && m[12] == 103,
            "column scatter: {m:?}");
        check!(m[1] == 0 && m[5] == 0, "holes untouched");
    }
    check_rc!(A::type_free(&mut col_t), "free");
    Ok(())
}

fn struct_layout<A: MpiAbi>(_r: usize) -> Result<(), String> {
    #[repr(C)]
    struct Particle {
        pos: [f64; 2],
        id: i32,
        flag: u8,
        // 3 bytes padding
    }
    let blocks = [
        (2i32, 0isize, A::datatype(Dt::Double)),
        (1i32, 16isize, A::datatype(Dt::Int)),
        (1i32, 20isize, A::datatype(Dt::Byte)),
    ];
    let mut t = A::datatype(Dt::Byte);
    check_rc!(A::type_create_struct(&blocks, &mut t), "struct");
    check_rc!(A::type_commit(&mut t), "commit");
    let mut size = 0;
    check_rc!(A::type_size(t, &mut size), "size");
    check!(size == 21, "packed struct size 21, got {size}");

    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    if n >= 2 {
        if me == 0 {
            let p = Particle { pos: [1.5, -2.5], id: 77, flag: 9 };
            check_rc!(A::send(ptr(&p), 1, t, 1, 6, A::comm_world()), "send struct");
        } else if me == 1 {
            let mut p = Particle { pos: [0.0, 0.0], id: 0, flag: 0 };
            let mut st = A::status_empty();
            check_rc!(A::recv(ptr_mut(&mut p), 1, t, 0, 6, A::comm_world(), &mut st), "recv");
            check!(p.pos == [1.5, -2.5] && p.id == 77 && p.flag == 9, "struct fields");
        }
    }
    check_rc!(A::type_free(&mut t), "free");
    Ok(())
}

fn dup_and_free<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut d = A::datatype(Dt::Byte);
    check_rc!(A::type_dup(A::datatype(Dt::Double), &mut d), "dup");
    let mut size = 0;
    check_rc!(A::type_size(d, &mut size), "size of dup");
    check!(size == 8, "dup keeps size");
    check_rc!(A::type_free(&mut d), "free dup");
    // Freeing a predefined type must fail (with errors returned).
    check_rc!(A::comm_set_errhandler(A::comm_world(), A::errhandler_return()), "errh");
    let mut builtin = A::datatype(Dt::Int);
    let rc = A::type_free(&mut builtin);
    check!(rc != 0, "freeing a builtin must fail");
    check_rc!(A::comm_set_errhandler(A::comm_world(), A::errhandler_fatal()), "errh restore");
    check_rc!(A::barrier(A::comm_world()), "resync");
    Ok(())
}

fn get_count_undefined<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    if n < 2 {
        return Ok(());
    }
    let dt_b = A::datatype(Dt::Byte);
    let dt_i = A::datatype(Dt::Int);
    if me == 0 {
        let v = [0u8; 6]; // 6 bytes: not a whole number of ints
        check_rc!(A::send(slice_ptr(&v), 6, dt_b, 1, 7, A::comm_world()), "send");
    } else if me == 1 {
        let mut v = [0u8; 6];
        let mut st = A::status_empty();
        check_rc!(A::recv(slice_ptr_mut(&mut v), 6, dt_b, 0, 7, A::comm_world(), &mut st), "recv");
        check!(A::get_count(&st, dt_b) == 6, "byte count 6");
        check!(A::get_count(&st, dt_i) == A::undefined(), "int count undefined");
    }
    Ok(())
}

/// `MPI_Get_count` against a *derived* datatype: a byte count that is
/// not a whole number of items must report `MPI_UNDEFINED`, and a whole
/// number of items must report the item count.
fn get_count_derived<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    if n < 2 {
        return Ok(());
    }
    let dt_b = A::datatype(Dt::Byte);
    // 3 ints per item (12 bytes packed).
    let mut tri = A::datatype(Dt::Byte);
    check_rc!(A::type_contiguous(3, A::datatype(Dt::Int32), &mut tri), "contiguous");
    check_rc!(A::type_commit(&mut tri), "commit");
    if me == 0 {
        let v = [0u8; 24];
        check_rc!(A::send(slice_ptr(&v), 24, dt_b, 1, 8, A::comm_world()), "send 24");
        check_rc!(A::send(slice_ptr(&v), 16, dt_b, 1, 9, A::comm_world()), "send 16");
    } else if me == 1 {
        let mut v = [0u8; 24];
        let mut st = A::status_empty();
        check_rc!(A::recv(slice_ptr_mut(&mut v), 24, dt_b, 0, 8, A::comm_world(), &mut st),
            "recv 24");
        check!(A::get_count(&st, tri) == 2, "24 bytes = 2 items");
        check_rc!(A::recv(slice_ptr_mut(&mut v), 16, dt_b, 0, 9, A::comm_world(), &mut st),
            "recv 16");
        check!(A::get_count(&st, tri) == A::undefined(),
            "16 bytes is not a whole number of 12-byte items");
    }
    check_rc!(A::type_free(&mut tri), "free");
    Ok(())
}

/// `MPI_Get_elements` resolves partial items to their basic leaves: 16
/// bytes of a 3-int item type is 4 whole ints, and a pair type counts
/// its two components separately.
fn get_elements_partial<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    if n < 2 {
        return Ok(());
    }
    let dt_b = A::datatype(Dt::Byte);
    let mut tri = A::datatype(Dt::Byte);
    check_rc!(A::type_contiguous(3, A::datatype(Dt::Int32), &mut tri), "contiguous");
    check_rc!(A::type_commit(&mut tri), "commit");
    if me == 0 {
        let v = [0u8; 16];
        check_rc!(A::send(slice_ptr(&v), 16, dt_b, 1, 8, A::comm_world()), "send 16");
        check_rc!(A::send(slice_ptr(&v), 6, dt_b, 1, 9, A::comm_world()), "send 6");
        check_rc!(A::send(slice_ptr(&v), 12, dt_b, 1, 10, A::comm_world()), "send 12");
    } else if me == 1 {
        let mut v = [0u8; 16];
        let mut st = A::status_empty();
        check_rc!(A::recv(slice_ptr_mut(&mut v), 16, dt_b, 0, 8, A::comm_world(), &mut st),
            "recv 16");
        // get_count: undefined (partial item); get_elements: 4 whole ints.
        check!(A::get_count(&st, tri) == A::undefined(), "partial item count undefined");
        check!(A::get_elements(&st, tri) == 4, "16 bytes = 4 basic ints, got {}",
            A::get_elements(&st, tri));
        check_rc!(A::recv(slice_ptr_mut(&mut v), 6, dt_b, 0, 9, A::comm_world(), &mut st),
            "recv 6");
        // 6 bytes splits the second int: elements undefined too.
        check!(A::get_elements(&st, tri) == A::undefined(), "split basic element");
        check_rc!(A::recv(slice_ptr_mut(&mut v), 12, dt_b, 0, 10, A::comm_world(), &mut st),
            "recv 12");
        // A pair type: 12 bytes = one and a half FLOAT_INT pairs = 3
        // basic elements.
        let fi = A::datatype(Dt::FloatInt);
        check!(A::get_count(&st, fi) == A::undefined(), "1.5 pairs undefined");
        check!(A::get_elements(&st, fi) == 3, "1.5 pairs = 3 basic elements, got {}",
            A::get_elements(&st, fi));
    }
    check_rc!(A::type_free(&mut tri), "free");
    Ok(())
}
