//! The job launcher — our `mpiexec`.
//!
//! Spawns one thread per rank, binds each to the shared [`World`], runs
//! the application closure, and collects per-rank outcomes. A rank that
//! panics unexpectedly triggers job abort (so peers blocked in recv
//! unwind instead of hanging), mirroring how a real launcher kills the
//! job when a process dies.

use std::panic::AssertUnwindSafe;
use std::sync::Arc;

use crate::core::transport::TransportKind;
use crate::core::world::{bind_rank, unbind_rank, AbortUnwind, KilledUnwind, World};

/// Job parameters.
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    pub ranks: usize,
    pub transport: TransportKind,
    /// Matching-engine override: `Some(true)` forces the flat-baseline
    /// matcher, `Some(false)` the indexed one, `None` defers to the
    /// `MPI_ABI_FLAT_MATCH` env flag (see [`crate::core::match_index`]).
    pub flat_match: Option<bool>,
    /// Eager/rendezvous switch override in packed bytes (`Some(0)`
    /// forces rendezvous for every non-empty message); `None` defers to
    /// the `MPI_ABI_RNDV_THRESHOLD` env var / 64 KiB default.
    pub rndv_threshold: Option<usize>,
    /// Event-tracing override: `Some(true)` records engine trace events
    /// on every rank, `None` defers to the `MPI_ABI_TRACE` env flag
    /// (see [`crate::core::obs`]).
    pub trace: Option<bool>,
    /// Deterministic rank-death injection: `(victim rank, progress ticks
    /// to survive)`. `None` defers to the `MPI_ABI_KILL` env var
    /// (`"rank:ticks"`). The victim unwinds mid-run; survivors observe
    /// `MPI_ERR_PROC_FAILED` instead of the job aborting.
    pub kill: Option<(usize, u64)>,
    /// Forced collective-algorithm choices (`0` per operation = the
    /// tuning table decides); `None` defers to the `MPI_ABI_COLL_ALGO`
    /// env var (see [`crate::core::collectives`]).
    pub coll_algo: Option<crate::core::collectives::CollAlgoForce>,
}

impl JobSpec {
    pub fn new(ranks: usize) -> JobSpec {
        JobSpec {
            ranks,
            transport: TransportKind::Spsc,
            flat_match: None,
            rndv_threshold: None,
            trace: None,
            kill: None,
            coll_algo: None,
        }
    }

    pub fn with_transport(mut self, t: TransportKind) -> JobSpec {
        self.transport = t;
        self
    }

    /// Force the matching mode for this job (tests/benches comparing
    /// flat vs indexed without racing on the process-global env var).
    pub fn with_flat_match(mut self, flat: bool) -> JobSpec {
        self.flat_match = Some(flat);
        self
    }

    /// Force the eager/rendezvous switch point for this job (tests and
    /// benches comparing protocols without racing on the env var).
    pub fn with_rndv_threshold(mut self, bytes: usize) -> JobSpec {
        self.rndv_threshold = Some(bytes);
        self
    }

    /// Enable (or force-disable) engine event tracing for this job
    /// without racing on the `MPI_ABI_TRACE` env flag.
    pub fn with_trace(mut self, on: bool) -> JobSpec {
        self.trace = Some(on);
        self
    }

    /// Arm the deterministic rank-death injector: `rank` dies after
    /// surviving `after_n_ticks` progress-engine cycles. The victim's
    /// outcome is [`RankOutcome::Killed`]; survivors keep running and see
    /// operations against it fail with `MPI_ERR_PROC_FAILED`.
    pub fn with_kill(mut self, rank: usize, after_n_ticks: u64) -> JobSpec {
        self.kill = Some((rank, after_n_ticks));
        self
    }

    /// Force collective-algorithm choices for this job (tests and
    /// benches comparing algorithms without racing on the env var).
    pub fn with_coll_algo(mut self, force: crate::core::collectives::CollAlgoForce) -> JobSpec {
        self.coll_algo = Some(force);
        self
    }
}

/// Parse the `MPI_ABI_KILL` env var (`"rank:ticks"`, e.g. `"1:50"`).
/// Malformed values are ignored (no kill) — an env typo should not
/// silently kill rank 0 at tick 0.
pub fn kill_env() -> Option<(usize, u64)> {
    let v = std::env::var("MPI_ABI_KILL").ok()?;
    let (r, t) = v.trim().split_once(':')?;
    Some((r.trim().parse().ok()?, t.trim().parse().ok()?))
}

/// Build a world from a spec, applying every override — the shared
/// prelude of [`run_job`] and [`run_job_traced`].
fn world_for(spec: JobSpec) -> Arc<World> {
    let world = World::new(spec.ranks, spec.transport);
    if let Some(flat) = spec.flat_match {
        world.set_flat_match(flat);
    }
    if let Some(t) = spec.rndv_threshold {
        world.set_rndv_threshold(t);
    }
    if let Some(on) = spec.trace {
        world.set_trace(on);
    }
    if let Some((rank, ticks)) = spec.kill.or_else(kill_env) {
        world.set_kill(rank, ticks);
    }
    if let Some(force) = spec.coll_algo {
        world.set_coll_algo(force);
    }
    world
}

/// Per-rank outcome.
#[derive(Debug)]
pub enum RankOutcome<T> {
    /// The rank's closure returned.
    Ok(T),
    /// The job aborted (`MPI_Abort` or fatal error handler) with this code.
    Aborted(i32),
    /// The rank was killed by the death injector ([`JobSpec::with_kill`]).
    /// Not a job failure: survivors run to completion.
    Killed,
    /// The rank panicked (bug in the application or library).
    Panicked(String),
}

impl<T> RankOutcome<T> {
    pub fn unwrap(self) -> T {
        match self {
            RankOutcome::Ok(v) => v,
            RankOutcome::Aborted(c) => panic!("rank aborted with code {c}"),
            RankOutcome::Killed => panic!("rank was killed by the death injector"),
            RankOutcome::Panicked(m) => panic!("rank panicked: {m}"),
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, RankOutcome::Ok(_))
    }
}

/// Run `f(rank)` on every rank of a fresh world. Blocks until all ranks
/// finish; returns outcomes in rank order.
pub fn run_job<T, F>(spec: JobSpec, f: F) -> Vec<RankOutcome<T>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_on_world(world_for(spec), spec.ranks, f)
}

/// Run a job and return the merged event trace alongside the outcomes.
///
/// The trace is the per-rank ring-buffer contents flushed at finalize
/// (or rank unbind), sorted by rank; it is empty unless tracing was
/// enabled via [`JobSpec::with_trace`] or `MPI_ABI_TRACE`. Feed it to
/// [`crate::core::obs::chrome_trace_json`] for a `chrome://tracing` /
/// Perfetto-loadable file.
pub fn run_job_traced<T, F>(
    spec: JobSpec,
    f: F,
) -> (Vec<RankOutcome<T>>, Vec<(usize, Vec<crate::core::obs::TraceEvent>)>)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let world = world_for(spec);
    let outcomes = run_on_world(world.clone(), spec.ranks, f);
    let trace = world.take_trace();
    (outcomes, trace)
}

/// Run on an existing world (used by benches that pre-create worlds).
pub fn run_on_world<T, F>(world: Arc<World>, ranks: usize, f: F) -> Vec<RankOutcome<T>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert_eq!(world.size, ranks);
    let f = &f;
    let mut outcomes: Vec<Option<RankOutcome<T>>> = (0..ranks).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..ranks)
            .map(|rank| {
                let world = world.clone();
                s.spawn(move || {
                    let _ctx = bind_rank(world.clone(), rank);
                    let result = std::panic::catch_unwind(AssertUnwindSafe(|| f(rank)));
                    unbind_rank();
                    match result {
                        Ok(v) => RankOutcome::Ok(v),
                        Err(payload) => {
                            if let Some(a) = payload.downcast_ref::<AbortUnwind>() {
                                RankOutcome::Aborted(a.0)
                            } else if payload.downcast_ref::<KilledUnwind>().is_some() {
                                // Injected death: survivors keep running.
                                RankOutcome::Killed
                            } else {
                                // Unexpected panic: take the whole job down
                                // so peers don't hang in blocking calls.
                                world.abort(1);
                                let msg = panic_message(&payload);
                                RankOutcome::Panicked(msg)
                            }
                        }
                    }
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            outcomes[rank] = Some(h.join().unwrap_or_else(|_| {
                RankOutcome::Panicked("rank thread join failed".to_string())
            }));
        }
    });
    outcomes.into_iter().map(|o| o.unwrap()).collect()
}

/// Run and unwrap all outcomes (panics if any rank failed). The common
/// test/app helper.
pub fn run_job_ok<T, F>(spec: JobSpec, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_job(spec, f).into_iter().map(|o| o.unwrap()).collect()
}

fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::engine;
    use crate::core::reserved::COMM_WORLD;
    use crate::core::transport::TransportKind;

    #[test]
    fn ranks_see_their_ids() {
        let out = run_job_ok(JobSpec::new(4), |rank| {
            engine::init().unwrap();
            let r = crate::core::comm::comm_rank(COMM_WORLD).unwrap();
            let s = crate::core::comm::comm_size(COMM_WORLD).unwrap();
            engine::finalize().unwrap();
            (rank, r, s)
        });
        for (i, (rank, r, s)) in out.into_iter().enumerate() {
            assert_eq!(rank, i);
            assert_eq!(r as usize, i);
            assert_eq!(s, 4);
        }
    }

    #[test]
    fn send_recv_roundtrip_both_transports() {
        for transport in [TransportKind::Spsc, TransportKind::Mutex] {
            let out = run_job_ok(JobSpec::new(2).with_transport(transport), |rank| {
                engine::init().unwrap();
                let dt = crate::core::datatype::builtin_id_of_abi(
                    crate::abi::datatypes::MPI_INT32_T,
                )
                .unwrap();
                let result = if rank == 0 {
                    let data = [1i32, 2, 3, 4];
                    engine::send(
                        data.as_ptr() as *const u8,
                        4,
                        dt,
                        1,
                        42,
                        COMM_WORLD,
                        engine::SendMode::Standard,
                    )
                    .unwrap();
                    vec![]
                } else {
                    let mut buf = [0i32; 4];
                    let st = engine::recv(buf.as_mut_ptr() as *mut u8, 4, dt, 0, 42, COMM_WORLD)
                        .unwrap();
                    assert_eq!(st.source, 0);
                    assert_eq!(st.tag, 42);
                    assert_eq!(st.count_bytes, 16);
                    buf.to_vec()
                };
                engine::finalize().unwrap();
                result
            });
            assert_eq!(out[1], vec![1, 2, 3, 4], "transport {transport:?}");
        }
    }

    #[test]
    fn abort_propagates_to_all_ranks() {
        let out = run_job(JobSpec::new(2), |rank| {
            engine::init().unwrap();
            if rank == 0 {
                let _ = engine::abort(7);
                unreachable!()
            }
            // Rank 1 blocks in a recv that can never match; job abort must
            // unwind it.
            let dt =
                crate::core::datatype::builtin_id_of_abi(crate::abi::datatypes::MPI_BYTE).unwrap();
            let mut b = [0u8; 1];
            let _ = engine::recv(b.as_mut_ptr(), 1, dt, 0, 9, COMM_WORLD);
        });
        assert!(matches!(out[0], RankOutcome::Aborted(7)));
        assert!(matches!(out[1], RankOutcome::Aborted(7)));
    }

    #[test]
    fn panicking_rank_takes_job_down() {
        let out = run_job(JobSpec::new(2), |rank| {
            engine::init().unwrap();
            if rank == 0 {
                panic!("application bug");
            }
            let dt =
                crate::core::datatype::builtin_id_of_abi(crate::abi::datatypes::MPI_BYTE).unwrap();
            let mut b = [0u8; 1];
            let _ = engine::recv(b.as_mut_ptr(), 1, dt, 0, 9, COMM_WORLD);
        });
        assert!(matches!(out[0], RankOutcome::Panicked(_)));
        assert!(matches!(out[1], RankOutcome::Aborted(1)));
    }
}
