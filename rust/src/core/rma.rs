//! One-sided communication (RMA): windows, epochs, and the Put/Get/
//! Accumulate data path.
//!
//! A window exposes a region of one rank's memory to its peers. Because
//! our "processes" are threads, we deliberately do **not** write remote
//! memory directly: every one-sided operation travels the transport
//! fabric as an *active message* on the window's dedicated context
//! planes, and is applied **by the target's own progress engine** — the
//! same single-threaded progress model the pt2pt and collective paths
//! use, and the reason no window memory is ever touched cross-thread.
//!
//! # Wire protocol
//!
//! Each window owns two context planes (allocated like a communicator's
//! pair, agreed collectively at creation):
//!
//! * **ops plane** (origin → target): `PUT`, `GET`, `ACC` requests plus
//!   passive-target `LOCKREQ`/`UNLOCK` control;
//! * **ctrl plane** (target → origin): `ACK` (op applied, with an error
//!   class), `GETREPLY` (requested bytes), `LOCKGRANT`, and the fence
//!   barrier rounds.
//!
//! Target layouts cross the wire as flattened `(offset, len)` byte runs
//! — the origin flattens its description of the target datatype via the
//! cached pack plans ([`crate::core::datatype::flatten`]), so the target
//! applies plain byte runs and never needs the origin's handles. Origin
//! data is packed with the same plans that serve sends.
//!
//! # The epoch state machine
//!
//! ```text
//!             MPI_Win_fence (no NOSUCCEED)
//!        ┌────────────────────────────────────┐
//!        ▼                                    │
//!      Fence ── MPI_Win_fence(NOSUCCEED) ──► None ◄──────────┐
//!                                             │              │
//!                                             │ MPI_Win_lock │ MPI_Win_unlock
//!                                             ▼              │
//!                                        Lock{target} ───────┘
//! ```
//!
//! Put/Get/Accumulate are erroneous (`MPI_ERR_RMA_SYNC`) outside an
//! epoch, and in a passive epoch only toward the locked target. An op
//! counts as *pending* until the target's ack (or get reply) returns;
//! fence, unlock, and flush drain the pending count — which is exactly
//! the "implementation-internal state leaking into the interface" that
//! makes RMA the sharpest ABI stress test.

use std::collections::{HashMap, VecDeque};

use super::comm::comm_snapshot;
use super::op::BUILTIN_ORDER;
use super::request::{enqueue_send, progress};
use super::transport::{Envelope, MsgKind, Payload};
use super::world::{with_ctx, RankCtx};
use super::{err, CommId, DtId, MpiError, OpId, WinId, RC};
use crate::abi::constants as k;
use crate::abi::errors as ec;

// --- Message tags on the window planes --------------------------------------

/// `Put` request (ops plane).
const TAG_PUT: i32 = 1;
/// `Get` request (ops plane); envelope `seq` carries the reply id.
const TAG_GET: i32 = 2;
/// `Accumulate` request (ops plane).
const TAG_ACC: i32 = 3;
/// Passive-target lock request (ops plane); payload is the lock type.
const TAG_LOCKREQ: i32 = 4;
/// Passive-target unlock (ops plane).
const TAG_UNLOCK: i32 = 5;
/// Op-applied ack (ctrl plane); payload is an error class (0 = ok).
const TAG_ACK: i32 = 10;
/// Get reply (ctrl plane); `seq` echoes the reply id.
const TAG_GETREPLY: i32 = 11;
/// Lock granted (ctrl plane).
const TAG_LOCKGRANT: i32 = 12;
/// Fence/free barrier rounds live above this tag; everything below is
/// routed to the RMA message handlers by the progress engine.
const FENCE_TAG_BASE: i32 = 1 << 24;

/// Origin-side access-epoch state. See the module docs for the diagram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Epoch {
    /// No epoch open: one-sided ops are erroneous.
    None,
    /// Fence epoch (between two `MPI_Win_fence` calls).
    Fence,
    /// Passive-target epoch to one locked target (window-group rank).
    Lock {
        /// The locked target's rank in the window group.
        target: i32,
    },
}

/// Target-side passive lock state of this rank's window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockState {
    /// Nobody holds the lock.
    Unlocked,
    /// `n` shared holders.
    Shared(u32),
    /// One exclusive holder (world rank).
    Exclusive(u32),
}

/// Where an outstanding `Get`'s bytes land when the reply arrives.
#[derive(Clone, Copy, Debug)]
pub struct GetDest {
    /// Origin buffer address.
    pub buf: usize,
    /// Origin element count.
    pub count: usize,
    /// Origin datatype.
    pub dt: DtId,
}

/// One RMA window: the exposed memory, the group, the two context
/// planes, and both sides of the synchronization state.
pub struct WinObj {
    /// Base address of the exposed local region.
    pub base: usize,
    /// Size of the exposed region in bytes.
    pub size: usize,
    /// Local displacement unit (bytes per `target_disp` step).
    pub disp_unit: usize,
    /// Member world ranks, in window-group rank order.
    pub members: Vec<usize>,
    /// This rank's rank within the window group.
    pub my_rank: usize,
    /// Context plane for origin→target requests.
    pub ctx_ops: u32,
    /// Context plane for target→origin replies and fence rounds.
    pub ctx_ctrl: u32,
    /// Origin-side epoch state.
    pub epoch: Epoch,
    /// Ops issued this epoch not yet acked by their targets.
    pub pending: u64,
    /// First error class a target reported for this epoch's ops.
    pub epoch_err: i32,
    /// Fence counter (keeps successive fences' barrier tags apart).
    pub fence_seq: u32,
    /// Outstanding gets: reply id → local destination.
    pub gets: HashMap<u64, GetDest>,
    /// Next get reply id.
    pub next_get_id: u64,
    /// Target-side passive lock state.
    pub lock: LockState,
    /// Queued lock requests: (origin world rank, canonical lock type).
    pub lock_queue: VecDeque<(u32, i32)>,
    /// Origin-side latch: our lock request has been granted.
    pub lock_granted: bool,
    /// Backing storage for `MPI_Win_allocate` windows.
    pub alloc: Option<Vec<u8>>,
}

/// Snapshot of the target-memory fields (applied without table borrows).
#[derive(Clone, Copy)]
struct WinMem {
    base: usize,
    size: usize,
    disp_unit: usize,
}

// ---------------------------------------------------------------------------
// Window lifecycle
// ---------------------------------------------------------------------------

/// `MPI_Win_create`: expose `size` bytes at `base`. Collective over
/// `comm`; the window's context planes are allocated by comm rank 0 and
/// broadcast, exactly like a communicator's context pair.
pub fn win_create(base: usize, size: usize, disp_unit: usize, comm: CommId) -> RC<WinId> {
    win_create_impl(base, size, disp_unit, comm, None)
}

/// `MPI_Win_allocate`: like [`win_create`], but the engine owns the
/// memory. Returns the window and the base address of the allocation.
pub fn win_allocate(size: usize, disp_unit: usize, comm: CommId) -> RC<(WinId, usize)> {
    let mem = vec![0u8; size];
    let base = mem.as_ptr() as usize;
    let id = win_create_impl(base, size, disp_unit, comm, Some(mem))?;
    Ok((id, base))
}

fn win_create_impl(
    base: usize,
    size: usize,
    disp_unit: usize,
    comm: CommId,
    alloc: Option<Vec<u8>>,
) -> RC<WinId> {
    if disp_unit == 0 {
        return Err(err!(MPI_ERR_DISP));
    }
    let (members, my_rank, _, _) = comm_snapshot(comm)?;
    // Rank 0 of the comm allocates the (ops, ctrl) plane pair.
    let mut bytes = [0u8; 8];
    if my_rank == 0 {
        let (a, b) = with_ctx(|ctx| Ok(ctx.world.alloc_context_pair()))?;
        bytes[..4].copy_from_slice(&a.to_le_bytes());
        bytes[4..].copy_from_slice(&b.to_le_bytes());
    }
    super::collectives::bcast_bytes(&mut bytes, 0, comm)?;
    let ctx_ops = u32::from_le_bytes(bytes[..4].try_into().unwrap());
    let ctx_ctrl = u32::from_le_bytes(bytes[4..].try_into().unwrap());
    let id = with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let id = t.wins.insert(WinObj {
            base,
            size,
            disp_unit,
            members: members.clone(),
            my_rank,
            ctx_ops,
            ctx_ctrl,
            epoch: Epoch::None,
            pending: 0,
            epoch_err: 0,
            fence_seq: 0,
            gets: HashMap::new(),
            next_get_id: 0,
            lock: LockState::Unlocked,
            lock_queue: VecDeque::new(),
            lock_granted: false,
            alloc,
        });
        t.win_by_ctx.insert(ctx_ops, id);
        t.win_by_ctx.insert(ctx_ctrl, id);
        Ok(WinId(id))
    })?;
    // Every rank registers the window before any one-sided traffic can
    // target it.
    super::collectives::barrier(comm)?;
    Ok(id)
}

/// `MPI_Win_free`. Collective. A passive-target epoch must be closed
/// (fence epochs are fine — freeing after a final fence is the normal
/// idiom); outstanding acks are drained, then a barrier over the window
/// group quiesces the planes before the window vanishes.
pub fn win_free(win: WinId) -> RC<()> {
    let (members, my_rank, ctrl, seq) = with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
        if matches!(w.epoch, Epoch::Lock { .. }) {
            return Err(err!(MPI_ERR_RMA_SYNC));
        }
        w.fence_seq = w.fence_seq.wrapping_add(1);
        Ok((w.members.clone(), w.my_rank, w.ctx_ctrl, w.fence_seq))
    })?;
    with_ctx(|ctx| {
        wait_pending(ctx, win)?;
        win_barrier(ctx, &members, my_rank, ctrl, seq);
        let mut t = ctx.tables.borrow_mut();
        if let Some(w) = t.wins.remove(win.0) {
            t.win_by_ctx.remove(&w.ctx_ops);
            t.win_by_ctx.remove(&w.ctx_ctrl);
        }
        Ok(())
    })
}

/// Window-group size (`MPI_Win_get_group` + `MPI_Group_size` shortcut).
pub fn win_size(win: WinId) -> RC<usize> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        Ok(t.wins.get(win.0).ok_or(err!(MPI_ERR_WIN))?.members.len())
    })
}

// ---------------------------------------------------------------------------
// Synchronization
// ---------------------------------------------------------------------------

/// `MPI_Win_fence`. Completes every op issued this epoch (waits for the
/// targets' acks), barriers the window group, and opens the next fence
/// epoch — unless `assert` carries `MPI_MODE_NOSUCCEED` (canonical
/// standard-ABI numbering), which closes the epoch instead.
pub fn win_fence(assert: i32, win: WinId) -> RC<()> {
    with_ctx(|ctx| {
        let (members, my_rank, ctrl, seq) = {
            let mut t = ctx.tables.borrow_mut();
            let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
            if matches!(w.epoch, Epoch::Lock { .. }) {
                return Err(err!(MPI_ERR_RMA_SYNC));
            }
            w.fence_seq = w.fence_seq.wrapping_add(1);
            (w.members.clone(), w.my_rank, w.ctx_ctrl, w.fence_seq)
        };
        super::obs::trace(ctx, super::obs::TraceKind::RmaEpoch, win.0, 0);
        wait_pending(ctx, win)?;
        win_barrier(ctx, &members, my_rank, ctrl, seq);
        let mut t = ctx.tables.borrow_mut();
        let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
        w.epoch = if assert & k::MPI_MODE_NOSUCCEED != 0 { Epoch::None } else { Epoch::Fence };
        let e = std::mem::replace(&mut w.epoch_err, 0);
        if e != 0 {
            return Err(MpiError::new(e));
        }
        Ok(())
    })
}

/// `MPI_Win_lock` (canonical lock types: `MPI_LOCK_EXCLUSIVE`/`_SHARED`
/// of the standard ABI). Blocks until the target grants the lock.
pub fn win_lock(lock_type: i32, rank: i32, _assert: i32, win: WinId) -> RC<()> {
    if lock_type != k::MPI_LOCK_EXCLUSIVE && lock_type != k::MPI_LOCK_SHARED {
        return Err(err!(MPI_ERR_LOCKTYPE));
    }
    with_ctx(|ctx| {
        let (target_world, ctx_ops) = {
            let mut t = ctx.tables.borrow_mut();
            let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
            if w.epoch != Epoch::None {
                return Err(err!(MPI_ERR_RMA_SYNC));
            }
            if rank < 0 || rank as usize >= w.members.len() {
                return Err(err!(MPI_ERR_RANK));
            }
            w.lock_granted = false;
            (w.members[rank as usize], w.ctx_ops)
        };
        super::obs::trace(ctx, super::obs::TraceKind::RmaEpoch, win.0, 1);
        if target_world == ctx.rank {
            // Local target: take the lock through the same state machine,
            // spinning so a remote holder's unlock (processed by our own
            // progress engine) can release it.
            loop {
                {
                    let mut t = ctx.tables.borrow_mut();
                    let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
                    if w.lock_queue.is_empty()
                        && try_take_lock(&mut w.lock, ctx.rank as u32, lock_type)
                    {
                        w.epoch = Epoch::Lock { target: rank };
                        return Ok(());
                    }
                }
                progress(ctx);
                std::thread::yield_now();
            }
        }
        let env = Envelope {
            src: ctx.rank as u32,
            context: ctx_ops,
            tag: TAG_LOCKREQ,
            kind: MsgKind::Eager,
            seq: 0,
            payload: Payload::from_slice(&lock_type.to_le_bytes()),
        };
        enqueue_send(ctx, target_world, env);
        loop {
            progress(ctx);
            {
                let mut t = ctx.tables.borrow_mut();
                let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
                if w.lock_granted {
                    w.lock_granted = false;
                    w.epoch = Epoch::Lock { target: rank };
                    return Ok(());
                }
            }
            std::thread::yield_now();
        }
    })
}

/// `MPI_Win_unlock`: completes every op of the passive epoch (origin
/// *and* target side — ops are acked only after application), releases
/// the target's lock, and closes the epoch.
pub fn win_unlock(rank: i32, win: WinId) -> RC<()> {
    with_ctx(|ctx| {
        let (target_world, ctx_ops) = {
            let t = ctx.tables.borrow();
            let w = t.wins.get(win.0).ok_or(err!(MPI_ERR_WIN))?;
            if w.epoch != (Epoch::Lock { target: rank }) {
                return Err(err!(MPI_ERR_RMA_SYNC));
            }
            (w.members[rank as usize], w.ctx_ops)
        };
        super::obs::trace(ctx, super::obs::TraceKind::RmaEpoch, win.0, 2);
        wait_pending(ctx, win)?;
        if target_world == ctx.rank {
            let grants = {
                let mut t = ctx.tables.borrow_mut();
                let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
                release_lock(w)
            };
            for (dst, ctrl) in grants {
                send_ctrl(ctx, dst, ctrl, TAG_LOCKGRANT, 0, Payload::empty());
            }
        } else {
            let env = Envelope {
                src: ctx.rank as u32,
                context: ctx_ops,
                tag: TAG_UNLOCK,
                kind: MsgKind::Eager,
                seq: 0,
                payload: Payload::empty(),
            };
            enqueue_send(ctx, target_world, env);
        }
        let mut t = ctx.tables.borrow_mut();
        let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
        w.epoch = Epoch::None;
        let e = std::mem::replace(&mut w.epoch_err, 0);
        if e != 0 {
            return Err(MpiError::new(e));
        }
        Ok(())
    })
}

/// `MPI_Win_flush`: completes all outstanding ops of the current passive
/// epoch at origin and target, without releasing the lock.
pub fn win_flush(_rank: i32, win: WinId) -> RC<()> {
    with_ctx(|ctx| {
        {
            let t = ctx.tables.borrow();
            let w = t.wins.get(win.0).ok_or(err!(MPI_ERR_WIN))?;
            if !matches!(w.epoch, Epoch::Lock { .. }) {
                return Err(err!(MPI_ERR_RMA_SYNC));
            }
        }
        wait_pending(ctx, win)?;
        let mut t = ctx.tables.borrow_mut();
        let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
        let e = std::mem::replace(&mut w.epoch_err, 0);
        if e != 0 {
            return Err(MpiError::new(e));
        }
        Ok(())
    })
}

/// Spin the progress engine until every op this origin issued on `win`
/// has been acked (the target applied it).
fn wait_pending(ctx: &RankCtx, win: WinId) -> RC<()> {
    loop {
        progress(ctx);
        {
            let t = ctx.tables.borrow();
            let w = t.wins.get(win.0).ok_or(err!(MPI_ERR_WIN))?;
            if w.pending == 0 {
                return Ok(());
            }
        }
        std::thread::yield_now();
    }
}

/// Dissemination barrier over the window group on the ctrl plane.
/// `seq` (the fence counter) keeps successive barriers' tags distinct.
fn win_barrier(ctx: &RankCtx, members: &[usize], my_rank: usize, ctx_ctrl: u32, seq: u32) {
    let n = members.len();
    if n <= 1 {
        return;
    }
    let mut k = 1usize;
    let mut round: i32 = 0;
    while k < n {
        let to_world = members[(my_rank + k) % n];
        let from_world = members[(my_rank + n - k) % n] as u32;
        let tag = FENCE_TAG_BASE + ((seq & 0xFFFF) as i32) * 64 + round;
        let env = Envelope {
            src: ctx.rank as u32,
            context: ctx_ctrl,
            tag,
            kind: MsgKind::Eager,
            seq: 0,
            payload: Payload::empty(),
        };
        enqueue_send(ctx, to_world, env);
        loop {
            progress(ctx);
            // Exact (src, tag) probe of the unexpected index — O(1).
            if ctx
                .state
                .borrow_mut()
                .match_index
                .take_unexpected(ctx_ctrl, from_world as i32, tag)
                .is_some()
            {
                break;
            }
            std::thread::yield_now();
        }
        k <<= 1;
        round += 1;
    }
}

// ---------------------------------------------------------------------------
// Data path: Put / Get / Accumulate
// ---------------------------------------------------------------------------

struct Route {
    target_world: usize,
    ctx_ops: u32,
}

/// Validate the epoch + target rank and resolve the wire route.
fn route(ctx: &RankCtx, win: WinId, target_rank: i32) -> RC<Route> {
    let t = ctx.tables.borrow();
    let w = t.wins.get(win.0).ok_or(err!(MPI_ERR_WIN))?;
    match w.epoch {
        Epoch::None => return Err(err!(MPI_ERR_RMA_SYNC)),
        Epoch::Lock { target } if target != target_rank => {
            return Err(err!(MPI_ERR_RMA_SYNC))
        }
        _ => {}
    }
    if target_rank < 0 || target_rank as usize >= w.members.len() {
        return Err(err!(MPI_ERR_RANK));
    }
    Ok(Route { target_world: w.members[target_rank as usize], ctx_ops: w.ctx_ops })
}

fn pack_origin(ctx: &RankCtx, buf: *const u8, count: usize, dt: DtId) -> RC<Vec<u8>> {
    let t = ctx.tables.borrow();
    let mut v = Vec::new();
    super::datatype::pack::pack(&t.dtypes, buf, count, dt, &mut v)?;
    Ok(v)
}

fn snapshot_mem(ctx: &RankCtx, win: WinId) -> RC<WinMem> {
    let t = ctx.tables.borrow();
    let w = t.wins.get(win.0).ok_or(err!(MPI_ERR_WIN))?;
    Ok(WinMem { base: w.base, size: w.size, disp_unit: w.disp_unit })
}

/// Register one in-flight op and ship its request to the target.
fn send_op(ctx: &RankCtx, win: WinId, r: &Route, tag: i32, seq: u64, payload: Payload) -> RC<()> {
    {
        let mut t = ctx.tables.borrow_mut();
        let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
        w.pending += 1;
    }
    let env = Envelope {
        src: ctx.rank as u32,
        context: r.ctx_ops,
        tag,
        kind: MsgKind::Eager,
        seq,
        payload,
    };
    enqueue_send(ctx, r.target_world, env);
    Ok(())
}

/// `MPI_Put`. The origin packs its data with the cached pack plans and
/// flattens the target datatype into byte runs; the target applies runs.
#[allow(clippy::too_many_arguments)]
pub fn put(
    origin: *const u8,
    origin_count: usize,
    origin_dt: DtId,
    target_rank: i32,
    target_disp: isize,
    target_count: usize,
    target_dt: DtId,
    win: WinId,
) -> RC<()> {
    with_ctx(|ctx| {
        let r = route(ctx, win, target_rank)?;
        if target_disp < 0 {
            return Err(err!(MPI_ERR_DISP));
        }
        let data = pack_origin(ctx, origin, origin_count, origin_dt)?;
        let segs = super::datatype::flatten(target_dt, target_count)?;
        let need: usize = segs.iter().map(|&(_, l)| l).sum();
        if need != data.len() {
            return Err(err!(MPI_ERR_SIZE));
        }
        if r.target_world == ctx.rank {
            let mem = snapshot_mem(ctx, win)?;
            let e = apply_put(&mem, target_disp, &segs, &data);
            if e != 0 {
                return Err(MpiError::new(e));
            }
            return Ok(());
        }
        send_op(ctx, win, &r, TAG_PUT, 0, encode_put(target_disp, &segs, &data))
    })
}

/// `MPI_Get`. The reply is unpacked into the origin buffer when it
/// arrives; the buffer is guaranteed valid after the closing fence,
/// flush, or unlock.
#[allow(clippy::too_many_arguments)]
pub fn get(
    origin: *mut u8,
    origin_count: usize,
    origin_dt: DtId,
    target_rank: i32,
    target_disp: isize,
    target_count: usize,
    target_dt: DtId,
    win: WinId,
) -> RC<()> {
    with_ctx(|ctx| {
        let r = route(ctx, win, target_rank)?;
        if target_disp < 0 {
            return Err(err!(MPI_ERR_DISP));
        }
        let segs = super::datatype::flatten(target_dt, target_count)?;
        let need: usize = segs.iter().map(|&(_, l)| l).sum();
        let osize = super::datatype::type_size(origin_dt)? * origin_count;
        if need != osize {
            return Err(err!(MPI_ERR_SIZE));
        }
        if r.target_world == ctx.rank {
            let mem = snapshot_mem(ctx, win)?;
            let data = read_get(&mem, target_disp, &segs).map_err(MpiError::new)?;
            let t = ctx.tables.borrow();
            super::datatype::pack::unpack(&t.dtypes, &data, origin, origin_count, origin_dt)?;
            return Ok(());
        }
        let reply_id = {
            let mut t = ctx.tables.borrow_mut();
            let w = t.wins.get_mut(win.0).ok_or(err!(MPI_ERR_WIN))?;
            w.next_get_id += 1;
            let id = w.next_get_id;
            w.gets.insert(
                id,
                GetDest { buf: origin as usize, count: origin_count, dt: origin_dt },
            );
            id
        };
        send_op(ctx, win, &r, TAG_GET, reply_id, encode_get(target_disp, &segs))
    })
}

/// `MPI_Accumulate` with a predefined op (user ops are not legal for
/// accumulate, per MPI). Origin and target datatypes must reduce to the
/// same single basic type; the target combines element-wise.
#[allow(clippy::too_many_arguments)]
pub fn accumulate(
    origin: *const u8,
    origin_count: usize,
    origin_dt: DtId,
    target_rank: i32,
    target_disp: isize,
    target_count: usize,
    target_dt: DtId,
    op: OpId,
    win: WinId,
) -> RC<()> {
    with_ctx(|ctx| {
        let r = route(ctx, win, target_rank)?;
        if target_disp < 0 {
            return Err(err!(MPI_ERR_DISP));
        }
        if op.0 == 0 || op.0 >= super::reserved::NUM_BUILTIN_OPS {
            return Err(err!(MPI_ERR_OP));
        }
        let leaf_o =
            super::datatype::leaf_builtin(origin_dt)?.ok_or(err!(MPI_ERR_TYPE))?;
        let leaf_t =
            super::datatype::leaf_builtin(target_dt)?.ok_or(err!(MPI_ERR_TYPE))?;
        if leaf_o != leaf_t {
            return Err(err!(MPI_ERR_TYPE));
        }
        let data = pack_origin(ctx, origin, origin_count, origin_dt)?;
        let segs = super::datatype::flatten(target_dt, target_count)?;
        let need: usize = segs.iter().map(|&(_, l)| l).sum();
        if need != data.len() {
            return Err(err!(MPI_ERR_SIZE));
        }
        if r.target_world == ctx.rank {
            let mem = snapshot_mem(ctx, win)?;
            let e = apply_acc(&mem, op.0, leaf_t, target_disp, &segs, &data);
            if e != 0 {
                return Err(MpiError::new(e));
            }
            return Ok(());
        }
        send_op(
            ctx,
            win,
            &r,
            TAG_ACC,
            0,
            encode_acc(op.0, leaf_t, target_disp, &segs, &data),
        )
    })
}

// ---------------------------------------------------------------------------
// Wire encoding (little-endian; both ends are this engine)
// ---------------------------------------------------------------------------

fn put_i32(v: &mut Vec<u8>, x: i32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_i64(v: &mut Vec<u8>, x: i64) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}

/// Bounds-checked little-endian reader over a request payload.
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let s = self.b.get(self.pos..self.pos + n)?;
        self.pos += n;
        Some(s)
    }
    fn i32(&mut self) -> Option<i32> {
        Some(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Option<i64> {
        Some(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn rest(self) -> &'a [u8] {
        &self.b[self.pos..]
    }
}

fn put_segs(v: &mut Vec<u8>, segs: &[(isize, usize)]) {
    put_u32(v, segs.len() as u32);
    for &(off, len) in segs {
        put_i64(v, off as i64);
        put_u64(v, len as u64);
    }
}

fn read_segs(rd: &mut Rd<'_>) -> Option<Vec<(isize, usize)>> {
    let n = rd.u32()? as usize;
    // A malformed count can't make us allocate unboundedly: each segment
    // costs 16 payload bytes, so the payload length bounds n.
    if n > rd.b.len() / 16 + 1 {
        return None;
    }
    let mut segs = Vec::with_capacity(n);
    for _ in 0..n {
        let off = rd.i64()? as isize;
        let len = rd.u64()? as usize;
        segs.push((off, len));
    }
    Some(segs)
}

fn encode_put(disp: isize, segs: &[(isize, usize)], data: &[u8]) -> Payload {
    let mut v = Vec::with_capacity(12 + segs.len() * 16 + data.len());
    put_i64(&mut v, disp as i64);
    put_segs(&mut v, segs);
    v.extend_from_slice(data);
    Payload::from_vec(v)
}

fn decode_put(b: &[u8]) -> Option<(isize, Vec<(isize, usize)>, &[u8])> {
    let mut rd = Rd::new(b);
    let disp = rd.i64()? as isize;
    let segs = read_segs(&mut rd)?;
    Some((disp, segs, rd.rest()))
}

fn encode_get(disp: isize, segs: &[(isize, usize)]) -> Payload {
    let mut v = Vec::with_capacity(12 + segs.len() * 16);
    put_i64(&mut v, disp as i64);
    put_segs(&mut v, segs);
    Payload::from_vec(v)
}

fn decode_get(b: &[u8]) -> Option<(isize, Vec<(isize, usize)>)> {
    let mut rd = Rd::new(b);
    let disp = rd.i64()? as isize;
    let segs = read_segs(&mut rd)?;
    Some((disp, segs))
}

fn encode_acc(
    op_idx: u32,
    abi_dt: usize,
    disp: isize,
    segs: &[(isize, usize)],
    data: &[u8],
) -> Payload {
    let mut v = Vec::with_capacity(24 + segs.len() * 16 + data.len());
    put_u32(&mut v, op_idx);
    put_u64(&mut v, abi_dt as u64);
    put_i64(&mut v, disp as i64);
    put_segs(&mut v, segs);
    v.extend_from_slice(data);
    Payload::from_vec(v)
}

#[allow(clippy::type_complexity)]
fn decode_acc(b: &[u8]) -> Option<(u32, usize, isize, Vec<(isize, usize)>, &[u8])> {
    let mut rd = Rd::new(b);
    let op_idx = rd.u32()?;
    let abi_dt = rd.u64()? as usize;
    let disp = rd.i64()? as isize;
    let segs = read_segs(&mut rd)?;
    Some((op_idx, abi_dt, disp, segs, rd.rest()))
}

// ---------------------------------------------------------------------------
// Target-side application (always on the window owner's own thread)
// ---------------------------------------------------------------------------

fn seg_range(mem: &WinMem, disp: isize, off: isize, len: usize) -> Result<usize, i32> {
    let o = disp
        .checked_mul(mem.disp_unit as isize)
        .and_then(|d| d.checked_add(off))
        .ok_or(ec::MPI_ERR_RMA_RANGE)?;
    if o < 0 || (o as usize).saturating_add(len) > mem.size {
        return Err(ec::MPI_ERR_RMA_RANGE);
    }
    Ok(mem.base + o as usize)
}

fn apply_put(mem: &WinMem, disp: isize, segs: &[(isize, usize)], data: &[u8]) -> i32 {
    let mut pos = 0usize;
    for &(off, len) in segs {
        if pos + len > data.len() {
            return ec::MPI_ERR_INTERN;
        }
        let dst = match seg_range(mem, disp, off, len) {
            Ok(a) => a,
            Err(e) => return e,
        };
        unsafe {
            std::ptr::copy_nonoverlapping(data.as_ptr().add(pos), dst as *mut u8, len);
        }
        pos += len;
    }
    0
}

fn read_get(mem: &WinMem, disp: isize, segs: &[(isize, usize)]) -> Result<Vec<u8>, i32> {
    let total: usize = segs.iter().map(|&(_, l)| l).sum();
    let mut out = Vec::with_capacity(total);
    for &(off, len) in segs {
        let src = seg_range(mem, disp, off, len)?;
        out.extend_from_slice(unsafe { std::slice::from_raw_parts(src as *const u8, len) });
    }
    Ok(out)
}

fn apply_acc(
    mem: &WinMem,
    op_idx: u32,
    abi_dt: usize,
    disp: isize,
    segs: &[(isize, usize)],
    data: &[u8],
) -> i32 {
    let Some(&b) = BUILTIN_ORDER.get(op_idx as usize) else {
        return ec::MPI_ERR_OP;
    };
    let kind = super::datatype::scalar_kind(abi_dt);
    let elem = crate::abi::datatypes::platform_size_of(abi_dt).unwrap_or(0);
    if elem == 0 {
        return ec::MPI_ERR_TYPE;
    }
    let mut pos = 0usize;
    for &(off, len) in segs {
        if pos + len > data.len() || len % elem != 0 {
            return ec::MPI_ERR_INTERN;
        }
        let dst = match seg_range(mem, disp, off, len) {
            Ok(a) => a,
            Err(e) => return e,
        };
        let inout = unsafe { std::slice::from_raw_parts_mut(dst as *mut u8, len) };
        let inbuf = &data[pos..pos + len];
        if let Err(e) = super::op::apply_builtin(b, kind, inbuf, inout, len / elem) {
            return e.class;
        }
        pos += len;
    }
    0
}

// ---------------------------------------------------------------------------
// Passive-target lock state machine (target side)
// ---------------------------------------------------------------------------

fn try_take_lock(lock: &mut LockState, origin: u32, lock_type: i32) -> bool {
    match *lock {
        LockState::Unlocked => {
            *lock = if lock_type == k::MPI_LOCK_EXCLUSIVE {
                LockState::Exclusive(origin)
            } else {
                LockState::Shared(1)
            };
            true
        }
        LockState::Shared(n) if lock_type == k::MPI_LOCK_SHARED => {
            *lock = LockState::Shared(n + 1);
            true
        }
        _ => false,
    }
}

/// Release one hold on the lock and grant every queued request that now
/// fits (one exclusive, or a run of shareds). Returns (origin world
/// rank, ctrl plane) pairs to send `LOCKGRANT`s to.
fn release_lock(w: &mut WinObj) -> Vec<(usize, u32)> {
    w.lock = match w.lock {
        LockState::Shared(n) if n > 1 => LockState::Shared(n - 1),
        _ => LockState::Unlocked,
    };
    let mut grants = Vec::new();
    while let Some(&(origin, lt)) = w.lock_queue.front() {
        if try_take_lock(&mut w.lock, origin, lt) {
            w.lock_queue.pop_front();
            grants.push((origin as usize, w.ctx_ctrl));
        } else {
            break;
        }
    }
    grants
}

// ---------------------------------------------------------------------------
// Progress integration
// ---------------------------------------------------------------------------

fn send_ctrl(ctx: &RankCtx, dst: usize, context: u32, tag: i32, seq: u64, payload: Payload) {
    let env = Envelope { src: ctx.rank as u32, context, tag, kind: MsgKind::Eager, seq, payload };
    enqueue_send(ctx, dst, env);
}

/// One RMA progress cycle: route every fabric arrival on a window plane
/// to its handler. Called from the engine's progress loop, so any rank
/// blocked in *any* MPI call services incoming one-sided traffic — that
/// is what makes passive-target epochs make progress.
pub(crate) fn progress_rma(ctx: &RankCtx) {
    loop {
        let found = {
            let mut st = ctx.state.borrow_mut();
            let t = ctx.tables.borrow();
            if t.win_by_ctx.is_empty() {
                return;
            }
            // Probe each window plane's unexpected queues for the next
            // data/control message (everything below the fence-barrier
            // tag band); per-plane arrival order is preserved.
            let mut hit = None;
            for (&cx, &w) in t.win_by_ctx.iter() {
                if let Some(env) = st.match_index.take_tag_below(cx, FENCE_TAG_BASE) {
                    hit = Some((w, env));
                    break;
                }
            }
            hit
        };
        let Some((w, env)) = found else { return };
        handle_msg(ctx, WinId(w), env);
    }
}

fn handle_msg(ctx: &RankCtx, win: WinId, env: Envelope) {
    match env.tag {
        TAG_PUT | TAG_GET | TAG_ACC => handle_request(ctx, win, env),
        TAG_LOCKREQ => handle_lock_req(ctx, win, env),
        TAG_UNLOCK => {
            let grants = {
                let mut t = ctx.tables.borrow_mut();
                match t.wins.get_mut(win.0) {
                    Some(w) => release_lock(w),
                    None => return,
                }
            };
            for (dst, ctrl) in grants {
                send_ctrl(ctx, dst, ctrl, TAG_LOCKGRANT, 0, Payload::empty());
            }
        }
        TAG_ACK => {
            let mut t = ctx.tables.borrow_mut();
            if let Some(w) = t.wins.get_mut(win.0) {
                w.pending = w.pending.saturating_sub(1);
                let e = env.payload.as_slice();
                let code = if e.len() >= 4 {
                    i32::from_le_bytes(e[..4].try_into().unwrap())
                } else {
                    ec::MPI_ERR_INTERN
                };
                if code != 0 && w.epoch_err == 0 {
                    w.epoch_err = code;
                }
            }
        }
        TAG_GETREPLY => handle_get_reply(ctx, win, env),
        TAG_LOCKGRANT => {
            let mut t = ctx.tables.borrow_mut();
            if let Some(w) = t.wins.get_mut(win.0) {
                w.lock_granted = true;
            }
        }
        _ => {} // unknown tag on a window plane: drop
    }
}

fn handle_request(ctx: &RankCtx, win: WinId, env: Envelope) {
    let origin = env.src as usize;
    let (mem, ctrl) = {
        let t = ctx.tables.borrow();
        let Some(w) = t.wins.get(win.0) else { return };
        (WinMem { base: w.base, size: w.size, disp_unit: w.disp_unit }, w.ctx_ctrl)
    };
    let data = env.payload.as_slice();
    match env.tag {
        TAG_PUT => {
            let code = match decode_put(data) {
                Some((disp, segs, body)) => apply_put(&mem, disp, &segs, body),
                None => ec::MPI_ERR_INTERN,
            };
            send_ctrl(ctx, origin, ctrl, TAG_ACK, 0, Payload::from_slice(&code.to_le_bytes()));
        }
        TAG_ACC => {
            let code = match decode_acc(data) {
                Some((op_idx, abi_dt, disp, segs, body)) => {
                    apply_acc(&mem, op_idx, abi_dt, disp, &segs, body)
                }
                None => ec::MPI_ERR_INTERN,
            };
            send_ctrl(ctx, origin, ctrl, TAG_ACK, 0, Payload::from_slice(&code.to_le_bytes()));
        }
        TAG_GET => {
            let (code, body) = match decode_get(data) {
                Some((disp, segs)) => match read_get(&mem, disp, &segs) {
                    Ok(v) => (0, v),
                    Err(e) => (e, Vec::new()),
                },
                None => (ec::MPI_ERR_INTERN, Vec::new()),
            };
            let mut p = Vec::with_capacity(4 + body.len());
            put_i32(&mut p, code);
            p.extend_from_slice(&body);
            send_ctrl(ctx, origin, ctrl, TAG_GETREPLY, env.seq, Payload::from_vec(p));
        }
        _ => unreachable!("handle_request only sees op tags"),
    }
}

fn handle_lock_req(ctx: &RankCtx, win: WinId, env: Envelope) {
    let p = env.payload.as_slice();
    let lock_type = if p.len() >= 4 {
        i32::from_le_bytes(p[..4].try_into().unwrap())
    } else {
        k::MPI_LOCK_SHARED
    };
    let origin = env.src;
    let grant = {
        let mut t = ctx.tables.borrow_mut();
        let Some(w) = t.wins.get_mut(win.0) else { return };
        if w.lock_queue.is_empty() && try_take_lock(&mut w.lock, origin, lock_type) {
            Some((origin as usize, w.ctx_ctrl))
        } else {
            w.lock_queue.push_back((origin, lock_type));
            None
        }
    };
    if let Some((dst, ctrl)) = grant {
        send_ctrl(ctx, dst, ctrl, TAG_LOCKGRANT, 0, Payload::empty());
    }
}

fn handle_get_reply(ctx: &RankCtx, win: WinId, env: Envelope) {
    let mut t = ctx.tables.borrow_mut();
    let tables = &mut *t;
    let Some(w) = tables.wins.get_mut(win.0) else { return };
    w.pending = w.pending.saturating_sub(1);
    let data = env.payload.as_slice();
    if data.len() < 4 {
        if w.epoch_err == 0 {
            w.epoch_err = ec::MPI_ERR_INTERN;
        }
        return;
    }
    let code = i32::from_le_bytes(data[..4].try_into().unwrap());
    let Some(dest) = w.gets.remove(&env.seq) else { return };
    if code != 0 {
        if w.epoch_err == 0 {
            w.epoch_err = code;
        }
        return;
    }
    if let Err(e) = super::datatype::pack::unpack(
        &tables.dtypes,
        &data[4..],
        dest.buf as *mut u8,
        dest.count,
        dest.dt,
    ) {
        // E.g. the origin freed its datatype before the closing sync
        // call: the buffer was not written, so the epoch must not
        // report success.
        if w.epoch_err == 0 {
            w.epoch_err = e.class;
        }
    }
}
