"""AOT path: lowering produces parseable HLO text for every artifact."""

import json

import jax
import jax.numpy as jnp

from compile import aot, model


def test_hlo_text_roundtrip_smoke():
    spec = jax.ShapeDtypeStruct((aot.REDUCE_SIZES[0],), jnp.float32)
    from compile.kernels.reduce import reduce_op

    lowered = jax.jit(lambda a, b: (reduce_op(a, b, op="sum"),)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "f32[4096]" in text


def test_lower_all_covers_expected_artifacts():
    names = [name for name, _, _ in aot.lower_all()]
    for op in ("sum", "prod", "min", "max"):
        for n in aot.REDUCE_SIZES:
            assert f"reduce_{op}_f32_{n}" in names
    assert "grad_step" in names
    assert "sgd_update" in names
    assert len(names) == 4 * len(aot.REDUCE_SIZES) + 2


def test_manifest_consistency(tmp_path):
    # Lower one artifact and check the manifest metadata matches shapes.
    for name, lowered, meta in aot.lower_all():
        if name == "sgd_update":
            assert meta["inputs"][-1] == ["f32", []]  # lr scalar
            assert len(meta["outputs"]) == 4
            break


def test_grad_step_hlo_mentions_model_shapes():
    args = model.example_args_grad_step()
    lowered = jax.jit(model.grad_step).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert f"f32[{model.BATCH},{model.D_IN}]" in text
