//! The two deliberately divergent MPI implementation ABIs.
//!
//! [`mpich`] uses MPICH's design: handles are C `int`s with kind bits and
//! (for builtin datatypes) the element size encoded in the handle value;
//! predefined constants are compile-time constants.
//!
//! [`ompi`] uses Open MPI's design: handles are pointers to descriptor
//! structs; predefined constants are addresses of global descriptors
//! (link-time, *not* compile-time constants); querying a datatype's size
//! dereferences the descriptor.
//!
//! Both are representation shims ([`repr::Repr`]) over the same engine —
//! exactly the situation of real MPI implementations sharing the MPI
//! semantics but differing in ABI, which is what makes translation
//! layers possible at all.
//!
//! The divergence extends to every handle kind the paper's table pins
//! down — including `MPI_Win`: an `int` with `T_WIN` kind bits here, a
//! `struct ompi_win_t *` there — and to the §5.4 integer constants
//! (MPICH's 234/235 lock types vs Open MPI's 1/2; Open MPI's dense
//! 1..16 assertion bits vs the 1024..16384 family).

#![warn(missing_docs)]

pub mod mpich;
pub mod ompi;
pub mod repr;

pub use mpich::MpichAbi;
pub use ompi::OmpiAbi;
