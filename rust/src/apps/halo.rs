//! 2-D Jacobi halo exchange — the classic MPI stencil workload (the kind
//! of application §4.7's containers ship). Decomposes a square grid over
//! a 1-D rank strip; each iteration exchanges boundary rows with both
//! neighbors and applies a 5-point stencil.
//!
//! Three exchange modes:
//!
//! * **blocking** (default): two `MPI_Sendrecv` calls per sweep — the
//!   classic textbook form;
//! * **persistent** ([`HaloMode::Persistent`]): four persistent
//!   requests per buffer created once (`MPI_Send_init`/`MPI_Recv_init`),
//!   then `MPI_Startall` + `MPI_Waitall` per sweep. Because the two grid
//!   buffers swap roles every sweep, one request set exists per buffer
//!   and the sweep's parity picks the set — the standard MPI idiom for
//!   persistent double buffering;
//! * **RMA** ([`HaloMode::Rma`]): one window per grid buffer; each sweep
//!   `MPI_Put`s the boundary rows straight into the neighbors' ghost
//!   rows and an `MPI_Win_fence` closes the exposure — no receives at
//!   all. The sweep's parity picks the window, mirroring the persistent
//!   request sets.
//!
//! Used by `examples/halo2d.rs` and the cross-ABI consistency tests: the
//! result must be bit-identical whichever ABI (and whichever exchange
//! mode) carries the halos.

use crate::api::{Dt, MpiAbi};

/// How the halo rows travel each sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HaloMode {
    /// Two `MPI_Sendrecv` calls per sweep.
    Sendrecv,
    /// Persistent requests, `MPI_Startall` + `MPI_Waitall` per sweep.
    Persistent,
    /// Fence-synchronized `MPI_Put`s into the neighbors' ghost rows.
    Rma,
}

impl HaloMode {
    /// Parse a CLI mode name.
    pub fn parse(s: &str) -> Option<HaloMode> {
        match s {
            "sendrecv" | "blocking" => Some(HaloMode::Sendrecv),
            "persistent" => Some(HaloMode::Persistent),
            "rma" => Some(HaloMode::Rma),
            _ => None,
        }
    }

    /// Canonical name (for reports).
    pub fn name(self) -> &'static str {
        match self {
            HaloMode::Sendrecv => "sendrecv",
            HaloMode::Persistent => "persistent",
            HaloMode::Rma => "rma",
        }
    }
}

/// Stencil configuration.
pub struct HaloParams {
    /// Global grid is `n x n`.
    pub n: usize,
    /// Number of Jacobi sweeps.
    pub iters: usize,
    /// Halo exchange mode.
    pub mode: HaloMode,
}

impl Default for HaloParams {
    fn default() -> Self {
        HaloParams { n: 64, iters: 20, mode: HaloMode::Sendrecv }
    }
}

/// Run the stencil over `MPI_COMM_WORLD`; returns (local residual sum,
/// global residual sum) after `iters` sweeps. Call from every rank.
pub fn jacobi<A: MpiAbi>(p: HaloParams) -> (f64, f64) {
    jacobi_on::<A>(A::comm_world(), p)
}

/// The **sessions-only** halo: initialize MPI through the MPI-4
/// sessions model — `MPI_Session_init` → `mpi://WORLD` pset → group →
/// `MPI_Comm_create_from_group` — run the stencil over the derived
/// communicator, and tear everything down, **without ever calling
/// `MPI_Init`**. The result must be bitwise identical to [`jacobi`]
/// under the world model, in every exchange mode, under every ABI
/// configuration (proved by `tests/session_halo.rs`).
pub fn jacobi_sessions<A: MpiAbi>(p: HaloParams) -> (f64, f64) {
    let mut session = A::session_null();
    let rc = A::session_init(A::info_null(), A::errhandler_return(), &mut session);
    assert_eq!(rc, 0, "session_init");
    let mut group = unsafe { std::mem::zeroed::<A::Group>() };
    let rc = A::group_from_session_pset(session, crate::core::session::PSET_WORLD, &mut group);
    assert_eq!(rc, 0, "group_from_session_pset");
    let mut comm = A::comm_null();
    let rc = A::comm_create_from_group(
        group,
        "mpi-abi://apps/halo",
        A::info_null(),
        A::errhandler_return(),
        &mut comm,
    );
    assert_eq!(rc, 0, "comm_create_from_group");
    A::group_free(&mut group);
    let out = jacobi_on::<A>(comm, p);
    A::comm_free(&mut comm);
    let rc = A::session_finalize(&mut session);
    assert_eq!(rc, 0, "session_finalize");
    out
}

/// Run the stencil over an arbitrary communicator (the world-model and
/// sessions-only entry points both land here).
pub fn jacobi_on<A: MpiAbi>(world: A::Comm, p: HaloParams) -> (f64, f64) {
    let (mut size, mut rank) = (0, 0);
    A::comm_size(world, &mut size);
    A::comm_rank(world, &mut rank);
    let dt = A::datatype(Dt::Double);
    let n = p.n;
    let rows_per = n / size as usize;
    assert!(rows_per >= 1, "grid too small for {size} ranks");
    let my_rows = if rank == size - 1 { n - rows_per * (size as usize - 1) } else { rows_per };

    // Local block with one ghost row above and below.
    let w = n;
    let h = my_rows + 2;
    let idx = |r: usize, c: usize| r * w + c;
    let mut grid = vec![0.0f64; w * h];
    let mut next = grid.clone();

    // Dirichlet boundary: global top row = 1.0 (only rank 0 owns it).
    if rank == 0 {
        for c in 0..w {
            grid[idx(1, c)] = 1.0;
            next[idx(1, c)] = 1.0;
        }
    }

    let up = if rank == 0 { A::proc_null() } else { rank - 1 };
    let down = if rank == size - 1 { A::proc_null() } else { rank + 1 };

    // Persistent mode: one request set per buffer, created once. The
    // four requests of a set carry the same traffic as the two Sendrecv
    // calls of the blocking path (tags 1 and 2 disambiguate direction).
    let mut req_sets: Vec<Vec<A::Request>> = Vec::new();
    if p.mode == HaloMode::Persistent {
        for buf in [&mut grid, &mut next] {
            // Derive every request pointer from a mutable borrow: the
            // receives write through them across sweeps.
            let base = buf.as_mut_ptr();
            let first_real = unsafe { base.add(idx(1, 0)) } as *const u8;
            let last_real = unsafe { base.add(idx(my_rows, 0)) } as *const u8;
            let ghost_top = unsafe { base.add(idx(0, 0)) } as *mut u8;
            let ghost_bot = unsafe { base.add(idx(my_rows + 1, 0)) } as *mut u8;
            let mut rs = vec![A::request_null(); 4];
            A::send_init(first_real, w as i32, dt, up, 1, world, &mut rs[0]);
            A::recv_init(ghost_bot, w as i32, dt, down, 1, world, &mut rs[1]);
            A::send_init(last_real, w as i32, dt, down, 2, world, &mut rs[2]);
            A::recv_init(ghost_top, w as i32, dt, up, 2, world, &mut rs[3]);
            req_sets.push(rs);
        }
    }

    // RMA mode: one window per buffer over the whole local block; the
    // sweep's parity picks the window (like the persistent sets). One
    // fence before the loop opens the first exposure epoch on both.
    let mut wins: Vec<A::Win> = Vec::new();
    if p.mode == HaloMode::Rma {
        for buf in [&mut grid, &mut next] {
            let mut win = A::win_null();
            A::win_create(
                buf.as_mut_ptr() as *mut u8,
                (w * h * std::mem::size_of::<f64>()) as crate::abi::types::Aint,
                std::mem::size_of::<f64>() as i32,
                A::info_null(),
                world,
                &mut win,
            );
            A::win_fence(0, win);
            wins.push(win);
        }
    }

    for it in 0..p.iters {
        match p.mode {
            HaloMode::Persistent => {
                // Start the set bound to whichever buffer is "grid" this
                // sweep, then wait all four halo transfers.
                let set = &mut req_sets[it % 2];
                A::startall(set);
                let mut sts = vec![A::status_empty(); 4];
                A::waitall(set, &mut sts);
            }
            HaloMode::Rma => {
                // Put my boundary rows straight into the neighbors'
                // ghost rows of the same-parity buffer; the fence
                // completes every put in the exposure epoch. The up
                // neighbor is never the last rank, so its ghost-bottom
                // row sits at (rows_per + 1) * w in displacement units.
                let win = wins[it % 2];
                let first_real = idx(1, 0);
                let last_real = idx(my_rows, 0);
                A::put(
                    grid[first_real..].as_ptr() as *const u8,
                    w as i32,
                    dt,
                    up,
                    ((rows_per + 1) * w) as crate::abi::types::Aint,
                    w as i32,
                    dt,
                    win,
                );
                A::put(
                    grid[last_real..].as_ptr() as *const u8,
                    w as i32,
                    dt,
                    down,
                    0,
                    w as i32,
                    dt,
                    win,
                );
                A::win_fence(0, win);
            }
            HaloMode::Sendrecv => {
                // Exchange: send my first real row up / receive ghost
                // from above, then send last real row down / receive
                // ghost from below.
                let mut st = A::status_empty();
                let first_real = idx(1, 0);
                let last_real = idx(my_rows, 0);
                let ghost_top = idx(0, 0);
                let ghost_bot = idx(my_rows + 1, 0);
                A::sendrecv(
                    grid[first_real..].as_ptr() as *const u8,
                    w as i32,
                    dt,
                    up,
                    1,
                    grid[ghost_bot..].as_mut_ptr() as *mut u8,
                    w as i32,
                    dt,
                    down,
                    1,
                    world,
                    &mut st,
                );
                A::sendrecv(
                    grid[last_real..].as_ptr() as *const u8,
                    w as i32,
                    dt,
                    down,
                    2,
                    grid[ghost_top..].as_mut_ptr() as *mut u8,
                    w as i32,
                    dt,
                    up,
                    2,
                    world,
                    &mut st,
                );
            }
        }

        // 5-point stencil on interior points (global boundary rows are
        // held fixed; the very first/last global rows never update).
        for r in 1..=my_rows {
            let global_r = rank as usize * rows_per + (r - 1);
            if global_r == 0 || global_r == n - 1 {
                for c in 0..w {
                    next[idx(r, c)] = grid[idx(r, c)];
                }
                continue;
            }
            for c in 1..w - 1 {
                next[idx(r, c)] = 0.25
                    * (grid[idx(r - 1, c)]
                        + grid[idx(r + 1, c)]
                        + grid[idx(r, c - 1)]
                        + grid[idx(r, c + 1)]);
            }
            next[idx(r, 0)] = grid[idx(r, 0)];
            next[idx(r, w - 1)] = grid[idx(r, w - 1)];
        }
        std::mem::swap(&mut grid, &mut next);
    }

    // Persistent requests are inactive after their last wait: free them.
    for set in req_sets.iter_mut() {
        for r in set.iter_mut() {
            A::request_free(r);
        }
    }

    // RMA windows: close the open fence epoch, then free collectively.
    for win in wins.iter_mut() {
        A::win_fence(A::mode_nosucceed(), *win);
        A::win_free(win);
    }

    // Residual: sum of interior values (a cheap convergence proxy).
    let local: f64 = (1..=my_rows).map(|r| (0..w).map(|c| grid[idx(r, c)]).sum::<f64>()).sum();
    let mut global = 0.0f64;
    A::allreduce(
        &local as *const f64 as *const u8,
        &mut global as *mut f64 as *mut u8,
        1,
        dt,
        A::op(crate::api::OpName::Sum),
        world,
    );
    (local, global)
}

/// The **fault-tolerant** halo (ULFM): run the stencil over
/// `MPI_COMM_WORLD` with a returning error handler, and when a rank
/// dies mid-run, recover with the ULFM sequence — `MPI_Comm_revoke`
/// (so every survivor's in-flight exchange fails instead of hanging),
/// `MPI_Comm_agree` (synchronize the failure view), `MPI_Comm_shrink`
/// (fresh communicator over the survivors) — then re-decompose the
/// grid over the shrunk communicator and rerun from the initial state.
///
/// Restarting from the initial state is the point, not a shortcut: it
/// makes the survivor result *bitwise identical* to a cold-start run on
/// the shrunk rank count, which is the cross-ABI acceptance check for
/// `abirun halo --kill` (and the property test's oracle). Exchanges use
/// `MPI_Sendrecv` regardless of `p.mode` — the FT recovery story is
/// about failure propagation, not transport variants.
///
/// Returns `(surviving comm size, global residual)`.
pub fn jacobi_ft<A: MpiAbi>(p: HaloParams) -> (i32, f64) {
    let world = A::comm_world();
    // Without this, the first MPI_ERR_PROC_FAILED would run the default
    // are-fatal handler and abort the job — ULFM apps always start by
    // making errors returnable.
    A::comm_set_errhandler(world, A::errhandler_return());
    let mut comm = world;
    loop {
        if let Some(out) = try_jacobi::<A>(comm, &p) {
            return out;
        }
        // A peer died (MPI_ERR_PROC_FAILED) or another survivor already
        // revoked the comm (MPI_ERR_REVOKED). Revoke is idempotent, so
        // every survivor runs the same sequence regardless of which
        // error it observed first.
        A::comm_revoke(comm);
        let mut ok = 1i32;
        A::comm_agree(comm, &mut ok);
        assert_eq!(ok, 1, "every survivor contributes 1 to the agreement");
        let mut next = A::comm_null();
        let rc = A::comm_shrink(comm, &mut next);
        assert_eq!(rc, 0, "comm_shrink");
        A::comm_set_errhandler(next, A::errhandler_return());
        comm = next;
    }
}

/// One attempt of the Sendrecv-mode stencil on `comm`, checking every
/// return code: `None` means an exchange or the residual reduction
/// failed (dead peer or revoked comm) and the caller should run ULFM
/// recovery. Success returns `(comm size, global residual)`.
fn try_jacobi<A: MpiAbi>(comm: A::Comm, p: &HaloParams) -> Option<(i32, f64)> {
    let (mut size, mut rank) = (0, 0);
    A::comm_size(comm, &mut size);
    A::comm_rank(comm, &mut rank);
    let dt = A::datatype(Dt::Double);
    let n = p.n;
    let rows_per = n / size as usize;
    assert!(rows_per >= 1, "grid too small for {size} ranks");
    let my_rows = if rank == size - 1 { n - rows_per * (size as usize - 1) } else { rows_per };

    let w = n;
    let h = my_rows + 2;
    let idx = |r: usize, c: usize| r * w + c;
    let mut grid = vec![0.0f64; w * h];
    let mut next = grid.clone();
    if rank == 0 {
        for c in 0..w {
            grid[idx(1, c)] = 1.0;
            next[idx(1, c)] = 1.0;
        }
    }

    let up = if rank == 0 { A::proc_null() } else { rank - 1 };
    let down = if rank == size - 1 { A::proc_null() } else { rank + 1 };

    for _ in 0..p.iters {
        let mut st = A::status_empty();
        let first_real = idx(1, 0);
        let last_real = idx(my_rows, 0);
        let ghost_top = idx(0, 0);
        let ghost_bot = idx(my_rows + 1, 0);
        let rc = A::sendrecv(
            grid[first_real..].as_ptr() as *const u8,
            w as i32,
            dt,
            up,
            1,
            grid[ghost_bot..].as_mut_ptr() as *mut u8,
            w as i32,
            dt,
            down,
            1,
            comm,
            &mut st,
        );
        if rc != 0 {
            return None;
        }
        let rc = A::sendrecv(
            grid[last_real..].as_ptr() as *const u8,
            w as i32,
            dt,
            down,
            2,
            grid[ghost_top..].as_mut_ptr() as *mut u8,
            w as i32,
            dt,
            up,
            2,
            comm,
            &mut st,
        );
        if rc != 0 {
            return None;
        }

        for r in 1..=my_rows {
            let global_r = rank as usize * rows_per + (r - 1);
            if global_r == 0 || global_r == n - 1 {
                for c in 0..w {
                    next[idx(r, c)] = grid[idx(r, c)];
                }
                continue;
            }
            for c in 1..w - 1 {
                next[idx(r, c)] = 0.25
                    * (grid[idx(r - 1, c)]
                        + grid[idx(r + 1, c)]
                        + grid[idx(r, c - 1)]
                        + grid[idx(r, c + 1)]);
            }
            next[idx(r, 0)] = grid[idx(r, 0)];
            next[idx(r, w - 1)] = grid[idx(r, w - 1)];
        }
        std::mem::swap(&mut grid, &mut next);
    }

    let local: f64 = (1..=my_rows).map(|r| (0..w).map(|c| grid[idx(r, c)]).sum::<f64>()).sum();
    let mut global = 0.0f64;
    let rc = A::allreduce(
        &local as *const f64 as *const u8,
        &mut global as *mut f64 as *mut u8,
        1,
        dt,
        A::op(crate::api::OpName::Sum),
        comm,
    );
    if rc != 0 {
        return None;
    }
    Some((size, global))
}
