//! The matching-semantics battery, standalone: all five ABI
//! configurations × both transports (the ISSUE-5 acceptance grid), plus
//! a flat-baseline run proving the indexed matcher and the seed's
//! linear scan produce identical semantics.

use mpi_abi::api::MpiAbi;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;
use mpi_abi::testsuite;

fn run_battery<A: MpiAbi>(ranks: usize, transport: TransportKind, flat: Option<bool>) {
    let mut spec = JobSpec::new(ranks).with_transport(transport);
    if let Some(f) = flat {
        spec = spec.with_flat_match(f);
    }
    let reports = run_job_ok(spec, |rank| {
        assert_eq!(A::init(), 0, "{} init", A::NAME);
        let results = testsuite::run_registry::<A>(rank, testsuite::matching_registry::<A>());
        let report = testsuite::report(A::NAME, &results);
        let failed = results.iter().filter(|r| !r.passed).count();
        assert_eq!(A::finalize(), 0, "{} finalize", A::NAME);
        (report, failed)
    });
    let (report, failures) = &reports[0];
    if *failures > 0 {
        panic!("[{} {:?} flat={flat:?}]\n{report}", A::NAME, transport);
    }
}

fn both_transports<A: MpiAbi>(ranks: usize) {
    run_battery::<A>(ranks, TransportKind::Spsc, None);
    run_battery::<A>(ranks, TransportKind::Mutex, None);
}

#[test]
fn matching_battery_mpich_native() {
    both_transports::<MpichAbi>(3);
}

#[test]
fn matching_battery_ompi_native() {
    both_transports::<OmpiAbi>(3);
}

#[test]
fn matching_battery_muk_over_mpich() {
    both_transports::<MukMpich>(3);
}

#[test]
fn matching_battery_muk_over_ompi() {
    both_transports::<MukOmpi>(3);
}

#[test]
fn matching_battery_native_standard_abi() {
    both_transports::<NativeAbi>(3);
}

#[test]
fn matching_battery_two_and_four_ranks() {
    both_transports::<NativeAbi>(2);
    both_transports::<MukMpich>(4);
}

/// The flat baseline (`MPI_ABI_FLAT_MATCH=1` semantics, forced per job
/// so parallel tests can't race on the env var) must pass the identical
/// battery on both transports: the index changes the complexity, never
/// the matching order.
#[test]
fn matching_battery_flat_baseline_identical() {
    run_battery::<NativeAbi>(3, TransportKind::Spsc, Some(true));
    run_battery::<NativeAbi>(3, TransportKind::Mutex, Some(true));
    run_battery::<MpichAbi>(3, TransportKind::Spsc, Some(true));
}
