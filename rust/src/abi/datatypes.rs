//! Predefined datatype handle constants (Appendix A.3) and the platform
//! size table.
//!
//! Datatypes own half the Huffman code space (`0b10…`/`0b11…`). Two
//! encoding classes exist:
//!
//! * **variable-size** (`0b1000xxxxxx`): C types whose width is a platform
//!   property (`int`, `long`, `float` …) plus the MPI integer types. Their
//!   size is *not* in the bits — encoding it would make the constant value
//!   a function of the platform ABI (§5.4).
//! * **fixed-size** (`0b1001_SSS_XXX`): width-`2^SSS` types; the size is
//!   readable with mask+shift ([`crate::abi::huffman::fixed_size_of`]).
//!
//! Values beyond the appendix excerpt (e.g. `MPI_DOUBLE`, Fortran types,
//! pair types for MINLOC/MAXLOC) are allocated in this module from the
//! reserved ranges, following the same grouping logic; they are marked
//! `// extension` below and are *our* allocation, not paper text.

/// Zero-page Huffman constant for `MPI_DATATYPE_NULL` (Appendix A.3).
pub const MPI_DATATYPE_NULL: usize = 0b1000000000;

// --- Variable-size types (0b1000xxxxxx) ------------------------------------

/// Zero-page Huffman constant for `MPI_AINT` (Appendix A.3).
pub const MPI_AINT: usize = 0b1000000001;
/// Zero-page Huffman constant for `MPI_COUNT` (Appendix A.3).
pub const MPI_COUNT: usize = 0b1000000010;
/// Zero-page Huffman constant for `MPI_OFFSET` (Appendix A.3).
pub const MPI_OFFSET: usize = 0b1000000011;
/// Zero-page Huffman constant for `MPI_PACKED` (Appendix A.3).
pub const MPI_PACKED: usize = 0b1000000111;

/// Zero-page Huffman constant for `MPI_SHORT` (Appendix A.3).
pub const MPI_SHORT: usize = 0b1000001000;
/// Zero-page Huffman constant for `MPI_INT` (Appendix A.3).
pub const MPI_INT: usize = 0b1000001001;
/// Zero-page Huffman constant for `MPI_LONG` (Appendix A.3).
pub const MPI_LONG: usize = 0b1000001010;
/// Zero-page Huffman constant for `MPI_LONG_LONG` (Appendix A.3).
pub const MPI_LONG_LONG: usize = 0b1000001011;
/// Alias required by the standard.
pub const MPI_LONG_LONG_INT: usize = MPI_LONG_LONG;
/// Zero-page Huffman constant for `MPI_UNSIGNED_SHORT` (Appendix A.3).
pub const MPI_UNSIGNED_SHORT: usize = 0b1000001100;
/// Zero-page Huffman constant for `MPI_UNSIGNED` (Appendix A.3).
pub const MPI_UNSIGNED: usize = 0b1000001101;
/// Zero-page Huffman constant for `MPI_UNSIGNED_LONG` (Appendix A.3).
pub const MPI_UNSIGNED_LONG: usize = 0b1000001110;
/// Zero-page Huffman constant for `MPI_UNSIGNED_LONG_LONG` (Appendix A.3).
pub const MPI_UNSIGNED_LONG_LONG: usize = 0b1000001111;
/// Zero-page Huffman constant for `MPI_FLOAT` (Appendix A.3).
pub const MPI_FLOAT: usize = 0b1000010000;
/// Zero-page Huffman constant for `MPI_DOUBLE` (Appendix A.3).
pub const MPI_DOUBLE: usize = 0b1000010001; // extension
/// Zero-page Huffman constant for `MPI_LONG_DOUBLE` (Appendix A.3).
pub const MPI_LONG_DOUBLE: usize = 0b1000010010; // extension
/// Zero-page Huffman constant for `MPI_C_BOOL` (Appendix A.3).
pub const MPI_C_BOOL: usize = 0b1000010011; // extension
/// Zero-page Huffman constant for `MPI_WCHAR` (Appendix A.3).
pub const MPI_WCHAR: usize = 0b1000010100; // extension
/// Zero-page Huffman constant for `MPI_C_COMPLEX` (Appendix A.3).
pub const MPI_C_COMPLEX: usize = 0b1000010101; // extension
/// Zero-page Huffman constant for `MPI_C_DOUBLE_COMPLEX` (Appendix A.3).
pub const MPI_C_DOUBLE_COMPLEX: usize = 0b1000010110; // extension
/// Zero-page Huffman constant for `MPI_C_LONG_DOUBLE_COMPLEX` (Appendix A.3).
pub const MPI_C_LONG_DOUBLE_COMPLEX: usize = 0b1000010111; // extension

// Fortran variable-size types (sizes track the Fortran compiler). extension
/// Zero-page Huffman constant for `MPI_INTEGER` (Appendix A.3).
pub const MPI_INTEGER: usize = 0b1000011000;
/// Zero-page Huffman constant for `MPI_REAL` (Appendix A.3).
pub const MPI_REAL: usize = 0b1000011001;
/// Zero-page Huffman constant for `MPI_DOUBLE_PRECISION` (Appendix A.3).
pub const MPI_DOUBLE_PRECISION: usize = 0b1000011010;
/// Zero-page Huffman constant for `MPI_COMPLEX` (Appendix A.3).
pub const MPI_COMPLEX: usize = 0b1000011011;
/// Zero-page Huffman constant for `MPI_DOUBLE_COMPLEX` (Appendix A.3).
pub const MPI_DOUBLE_COMPLEX: usize = 0b1000011100;
/// Zero-page Huffman constant for `MPI_LOGICAL` (Appendix A.3).
pub const MPI_LOGICAL: usize = 0b1000011101;
/// Zero-page Huffman constant for `MPI_CHARACTER` (Appendix A.3).
pub const MPI_CHARACTER: usize = 0b1000011110;

// Pair types for MINLOC/MAXLOC (typemaps, not single scalars). extension
/// Zero-page Huffman constant for `MPI_FLOAT_INT` (Appendix A.3).
pub const MPI_FLOAT_INT: usize = 0b1000100000;
/// Zero-page Huffman constant for `MPI_DOUBLE_INT` (Appendix A.3).
pub const MPI_DOUBLE_INT: usize = 0b1000100001;
/// Zero-page Huffman constant for `MPI_LONG_INT` (Appendix A.3).
pub const MPI_LONG_INT: usize = 0b1000100010;
/// Zero-page Huffman constant for `MPI_2INT` (Appendix A.3).
pub const MPI_2INT: usize = 0b1000100011;
/// Zero-page Huffman constant for `MPI_SHORT_INT` (Appendix A.3).
pub const MPI_SHORT_INT: usize = 0b1000100100;
/// Zero-page Huffman constant for `MPI_LONG_DOUBLE_INT` (Appendix A.3).
pub const MPI_LONG_DOUBLE_INT: usize = 0b1000100101;
/// Zero-page Huffman constant for `MPI_2REAL` (Appendix A.3).
pub const MPI_2REAL: usize = 0b1000100110;
/// Zero-page Huffman constant for `MPI_2DOUBLE_PRECISION` (Appendix A.3).
pub const MPI_2DOUBLE_PRECISION: usize = 0b1000100111;
/// Zero-page Huffman constant for `MPI_2INTEGER` (Appendix A.3).
pub const MPI_2INTEGER: usize = 0b1000101000;

// --- Fixed-size types (0b1001_SSS_XXX, size = 2^SSS) ------------------------

// size 1 (SSS=000)
/// Zero-page Huffman constant for `MPI_INT8_T` (Appendix A.3).
pub const MPI_INT8_T: usize = 0b1001000000;
/// Zero-page Huffman constant for `MPI_UINT8_T` (Appendix A.3).
pub const MPI_UINT8_T: usize = 0b1001000001;
// 0b1001000010 is reserved for a future 8-bit float in A.3.
/// Zero-page Huffman constant for `MPI_CHAR` (Appendix A.3).
pub const MPI_CHAR: usize = 0b1001000011;
/// Zero-page Huffman constant for `MPI_SIGNED_CHAR` (Appendix A.3).
pub const MPI_SIGNED_CHAR: usize = 0b1001000100;
/// Zero-page Huffman constant for `MPI_UNSIGNED_CHAR` (Appendix A.3).
pub const MPI_UNSIGNED_CHAR: usize = 0b1001000101;
/// Zero-page Huffman constant for `MPI_BYTE` (Appendix A.3).
pub const MPI_BYTE: usize = 0b1001000111;

// size 2 (SSS=001)
/// Zero-page Huffman constant for `MPI_INT16_T` (Appendix A.3).
pub const MPI_INT16_T: usize = 0b1001001000;
/// Zero-page Huffman constant for `MPI_UINT16_T` (Appendix A.3).
pub const MPI_UINT16_T: usize = 0b1001001001;
/// `<float 16b>` in A.3 — a future half-precision type; named here because
/// our compute path (bf16/f16 tiles) exercises it. extension (name only)
pub const MPI_FLOAT16_T: usize = 0b1001001010;

// size 4 (SSS=010)
/// Zero-page Huffman constant for `MPI_INT32_T` (Appendix A.3).
pub const MPI_INT32_T: usize = 0b1001010000;
/// Zero-page Huffman constant for `MPI_UINT32_T` (Appendix A.3).
pub const MPI_UINT32_T: usize = 0b1001010001;
/// `<C float 32b>` in A.3. extension (name only)
pub const MPI_FLOAT32_T: usize = 0b1001010010;
/// `<C complex 2x16b>` in A.3. extension (name only)
pub const MPI_COMPLEX32_T: usize = 0b1001010011;

// size 8 (SSS=011)
/// Zero-page Huffman constant for `MPI_INT64_T` (Appendix A.3).
pub const MPI_INT64_T: usize = 0b1001011000;
/// Zero-page Huffman constant for `MPI_UINT64_T` (Appendix A.3).
pub const MPI_UINT64_T: usize = 0b1001011001;
/// `<C float64>` in A.3. extension (name only)
pub const MPI_FLOAT64_T: usize = 0b1001011010;
/// `<C complex 2x32b>` in A.3. extension (name only)
pub const MPI_COMPLEX64_T: usize = 0b1001011011;

// size 16 (SSS=100). extension
/// Zero-page Huffman constant for `MPI_COMPLEX128_T` (Appendix A.3).
pub const MPI_COMPLEX128_T: usize = 0b1001100011;

/// Everything predefined in the datatype space, with MPI names.
pub const PREDEFINED_DATATYPES: &[(&str, usize)] = &[
    ("MPI_DATATYPE_NULL", MPI_DATATYPE_NULL),
    ("MPI_AINT", MPI_AINT),
    ("MPI_COUNT", MPI_COUNT),
    ("MPI_OFFSET", MPI_OFFSET),
    ("MPI_PACKED", MPI_PACKED),
    ("MPI_SHORT", MPI_SHORT),
    ("MPI_INT", MPI_INT),
    ("MPI_LONG", MPI_LONG),
    ("MPI_LONG_LONG", MPI_LONG_LONG),
    ("MPI_UNSIGNED_SHORT", MPI_UNSIGNED_SHORT),
    ("MPI_UNSIGNED", MPI_UNSIGNED),
    ("MPI_UNSIGNED_LONG", MPI_UNSIGNED_LONG),
    ("MPI_UNSIGNED_LONG_LONG", MPI_UNSIGNED_LONG_LONG),
    ("MPI_FLOAT", MPI_FLOAT),
    ("MPI_DOUBLE", MPI_DOUBLE),
    ("MPI_LONG_DOUBLE", MPI_LONG_DOUBLE),
    ("MPI_C_BOOL", MPI_C_BOOL),
    ("MPI_WCHAR", MPI_WCHAR),
    ("MPI_C_COMPLEX", MPI_C_COMPLEX),
    ("MPI_C_DOUBLE_COMPLEX", MPI_C_DOUBLE_COMPLEX),
    ("MPI_C_LONG_DOUBLE_COMPLEX", MPI_C_LONG_DOUBLE_COMPLEX),
    ("MPI_INTEGER", MPI_INTEGER),
    ("MPI_REAL", MPI_REAL),
    ("MPI_DOUBLE_PRECISION", MPI_DOUBLE_PRECISION),
    ("MPI_COMPLEX", MPI_COMPLEX),
    ("MPI_DOUBLE_COMPLEX", MPI_DOUBLE_COMPLEX),
    ("MPI_LOGICAL", MPI_LOGICAL),
    ("MPI_CHARACTER", MPI_CHARACTER),
    ("MPI_FLOAT_INT", MPI_FLOAT_INT),
    ("MPI_DOUBLE_INT", MPI_DOUBLE_INT),
    ("MPI_LONG_INT", MPI_LONG_INT),
    ("MPI_2INT", MPI_2INT),
    ("MPI_SHORT_INT", MPI_SHORT_INT),
    ("MPI_LONG_DOUBLE_INT", MPI_LONG_DOUBLE_INT),
    ("MPI_2REAL", MPI_2REAL),
    ("MPI_2DOUBLE_PRECISION", MPI_2DOUBLE_PRECISION),
    ("MPI_2INTEGER", MPI_2INTEGER),
    ("MPI_INT8_T", MPI_INT8_T),
    ("MPI_UINT8_T", MPI_UINT8_T),
    ("MPI_CHAR", MPI_CHAR),
    ("MPI_SIGNED_CHAR", MPI_SIGNED_CHAR),
    ("MPI_UNSIGNED_CHAR", MPI_UNSIGNED_CHAR),
    ("MPI_BYTE", MPI_BYTE),
    ("MPI_INT16_T", MPI_INT16_T),
    ("MPI_UINT16_T", MPI_UINT16_T),
    ("MPI_FLOAT16_T", MPI_FLOAT16_T),
    ("MPI_INT32_T", MPI_INT32_T),
    ("MPI_UINT32_T", MPI_UINT32_T),
    ("MPI_FLOAT32_T", MPI_FLOAT32_T),
    ("MPI_COMPLEX32_T", MPI_COMPLEX32_T),
    ("MPI_INT64_T", MPI_INT64_T),
    ("MPI_UINT64_T", MPI_UINT64_T),
    ("MPI_FLOAT64_T", MPI_FLOAT64_T),
    ("MPI_COMPLEX64_T", MPI_COMPLEX64_T),
    ("MPI_COMPLEX128_T", MPI_COMPLEX128_T),
];

/// Size in bytes of a predefined datatype **on this platform**.
///
/// Fixed-size encodings come straight from the handle bits; variable-size
/// types resolve to this platform's C/Fortran widths (LP64 assumptions,
/// `MPI_INTEGER`/`MPI_LOGICAL`/`MPI_REAL` = 4 as with default Fortran
/// flags). `MPI_DATATYPE_NULL` and `MPI_PACKED` report size 1 byte-unit.
pub fn platform_size_of(dt: usize) -> Option<usize> {
    if let Some(s) = crate::abi::huffman::fixed_size_of(dt) {
        return Some(s);
    }
    Some(match dt {
        MPI_AINT => core::mem::size_of::<crate::abi::types::Aint>(),
        MPI_COUNT => 8,
        MPI_OFFSET => 8,
        MPI_PACKED => 1,
        MPI_SHORT => 2,
        MPI_INT => 4,
        MPI_LONG => core::mem::size_of::<core::ffi::c_long>(),
        MPI_LONG_LONG => 8,
        MPI_UNSIGNED_SHORT => 2,
        MPI_UNSIGNED => 4,
        MPI_UNSIGNED_LONG => core::mem::size_of::<core::ffi::c_ulong>(),
        MPI_UNSIGNED_LONG_LONG => 8,
        MPI_FLOAT => 4,
        MPI_DOUBLE => 8,
        MPI_LONG_DOUBLE => 16,
        MPI_C_BOOL => 1,
        MPI_WCHAR => 4,
        MPI_C_COMPLEX => 8,
        MPI_C_DOUBLE_COMPLEX => 16,
        MPI_C_LONG_DOUBLE_COMPLEX => 32,
        MPI_INTEGER => 4,
        MPI_REAL => 4,
        MPI_DOUBLE_PRECISION => 8,
        MPI_COMPLEX => 8,
        MPI_DOUBLE_COMPLEX => 16,
        MPI_LOGICAL => 4,
        MPI_CHARACTER => 1,
        MPI_FLOAT_INT => 8,
        MPI_DOUBLE_INT => 12,
        MPI_LONG_INT => core::mem::size_of::<core::ffi::c_long>() + 4,
        MPI_2INT => 8,
        MPI_SHORT_INT => 6,
        MPI_LONG_DOUBLE_INT => 20,
        MPI_2REAL => 8,
        MPI_2DOUBLE_PRECISION => 16,
        MPI_2INTEGER => 8,
        MPI_DATATYPE_NULL => return None,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abi::huffman::{datatype_class, fixed_size_of, kind_of, DatatypeClass, HandleKind};

    #[test]
    fn every_datatype_is_datatype_kind() {
        for &(name, v) in PREDEFINED_DATATYPES {
            assert_eq!(kind_of(v as u16), HandleKind::Datatype, "{name}");
        }
    }

    #[test]
    fn fixed_size_bits_match_platform_size() {
        // Where the encoding carries a size, it must agree with the table.
        for &(name, v) in PREDEFINED_DATATYPES {
            if let Some(bits_size) = fixed_size_of(v) {
                assert_eq!(platform_size_of(v), Some(bits_size), "{name}");
            }
        }
    }

    #[test]
    fn variable_size_types_do_not_encode_size() {
        for v in [MPI_INT, MPI_LONG, MPI_FLOAT, MPI_DOUBLE, MPI_AINT] {
            assert_eq!(datatype_class(v), DatatypeClass::VariableSize);
            assert_eq!(fixed_size_of(v), None);
        }
    }

    #[test]
    fn long_long_alias() {
        assert_eq!(MPI_LONG_LONG, MPI_LONG_LONG_INT);
    }

    #[test]
    fn sizes_are_sane() {
        assert_eq!(platform_size_of(MPI_INT), Some(4));
        assert_eq!(platform_size_of(MPI_DOUBLE), Some(8));
        assert_eq!(platform_size_of(MPI_BYTE), Some(1));
        assert_eq!(platform_size_of(MPI_AINT), Some(core::mem::size_of::<usize>()));
        assert_eq!(platform_size_of(MPI_DATATYPE_NULL), None);
    }

    #[test]
    fn a3_reserved_float8_slot_untouched() {
        // 0b1001000010 is `<float 8b>` in A.3: reserved, not named by us.
        assert!(!PREDEFINED_DATATYPES.iter().any(|&(_, v)| v == 0b1001000010));
        // But its *encoding* already promises size 1:
        assert_eq!(fixed_size_of(0b1001000010), Some(1));
    }
}
