//! Communicator, group, error-handler, attribute and info tests.

use std::cell::Cell;

use super::util::*;
use super::TestFn;
use crate::api::{Dt, MpiAbi};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("comm.dup_isolated_traffic", dup_isolated_traffic::<A>),
        ("comm.split_even_odd", split_even_odd::<A>),
        ("comm.split_undefined", split_undefined::<A>),
        ("comm.split_type_shared", split_type_shared::<A>),
        ("comm.split_type_undefined", split_type_undefined::<A>),
        ("comm.compare", compare::<A>),
        ("comm.names", names::<A>),
        ("comm.groups", groups::<A>),
        ("comm.errhandler_custom", errhandler_custom::<A>),
        ("comm.attributes", attributes::<A>),
        ("comm.attr_callbacks_on_dup", attr_callbacks_on_dup::<A>),
        ("comm.info", info::<A>),
    ]
}

fn geom<A: MpiAbi>() -> (i32, i32) {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    (n, me)
}

fn dup_isolated_traffic<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let mut dup = A::comm_null();
    check_rc!(A::comm_dup(A::comm_world(), &mut dup), "dup");
    check!(dup != A::comm_null(), "dup produced a comm");
    if n >= 2 {
        let dt = A::datatype(Dt::Int);
        if me == 0 {
            let a = [1i32];
            let b = [2i32];
            check_rc!(A::send(slice_ptr(&a), 1, dt, 1, 7, A::comm_world()), "send world");
            check_rc!(A::send(slice_ptr(&b), 1, dt, 1, 7, dup), "send dup");
        } else if me == 1 {
            // Opposite receive order: contexts must disambiguate.
            let mut b = [0i32];
            let mut st = A::status_empty();
            check_rc!(A::recv(slice_ptr_mut(&mut b), 1, dt, 0, 7, dup, &mut st), "recv dup");
            check!(b[0] == 2, "dup traffic: {}", b[0]);
            let mut a = [0i32];
            check_rc!(A::recv(slice_ptr_mut(&mut a), 1, dt, 0, 7, A::comm_world(), &mut st),
                "recv world");
            check!(a[0] == 1, "world traffic: {}", a[0]);
        }
    }
    check_rc!(A::comm_free(&mut dup), "free");
    check!(dup == A::comm_null(), "handle reset to null");
    Ok(())
}

fn split_even_odd<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let mut sub = A::comm_null();
    check_rc!(A::comm_split(A::comm_world(), me % 2, me, &mut sub), "split");
    let (mut sn, mut sr) = (0, 0);
    check_rc!(A::comm_size(sub, &mut sn), "sub size");
    check_rc!(A::comm_rank(sub, &mut sr), "sub rank");
    let want_n = if me % 2 == 0 { (n + 1) / 2 } else { n / 2 };
    check!(sn == want_n, "subcomm size {sn} want {want_n}");
    check!(sr == me / 2, "subcomm rank {sr} want {}", me / 2);
    // Use it.
    let dt = A::datatype(Dt::Int);
    let send = [1i32];
    let mut total = [0i32];
    check_rc!(
        A::allreduce(slice_ptr(&send), slice_ptr_mut(&mut total), 1, dt,
            A::op(crate::api::OpName::Sum), sub),
        "allreduce on sub"
    );
    check!(total[0] == sn, "sub allreduce");
    check_rc!(A::comm_free(&mut sub), "free");
    Ok(())
}

fn split_undefined<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (_n, me) = geom::<A>();
    let color = if me == 0 { A::undefined() } else { 0 };
    let mut sub = A::comm_null();
    check_rc!(A::comm_split(A::comm_world(), color, 0, &mut sub), "split");
    if me == 0 {
        check!(sub == A::comm_null(), "UNDEFINED color yields COMM_NULL");
    } else {
        check!(sub != A::comm_null(), "others get a comm");
        check_rc!(A::comm_free(&mut sub), "free");
    }
    Ok(())
}

fn split_type_shared<A: MpiAbi>(_r: usize) -> Result<(), String> {
    // Thread-ranks all share memory: COMM_TYPE_SHARED must reproduce
    // the whole communicator, ordered by key.
    let (n, me) = geom::<A>();
    let mut sub = A::comm_null();
    check_rc!(
        A::comm_split_type(A::comm_world(), A::comm_type_shared(), n - 1 - me, &mut sub),
        "split_type"
    );
    check!(sub != A::comm_null(), "shared split yields a comm");
    let (mut sn, mut sr) = (0, 0);
    check_rc!(A::comm_size(sub, &mut sn), "sub size");
    check_rc!(A::comm_rank(sub, &mut sr), "sub rank");
    check!(sn == n, "shared node comm spans all {n} thread-ranks, got {sn}");
    check!(sr == n - 1 - me, "key reverses rank order: {sr}");
    // Use it: an allreduce proves the new context planes work.
    let dt = A::datatype(Dt::Int);
    let send = [1i32];
    let mut total = [0i32];
    check_rc!(
        A::allreduce(slice_ptr(&send), slice_ptr_mut(&mut total), 1, dt,
            A::op(crate::api::OpName::Sum), sub),
        "allreduce on node comm"
    );
    check!(total[0] == n, "node comm allreduce");
    check_rc!(A::comm_free(&mut sub), "free");
    Ok(())
}

fn split_type_undefined<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (_n, me) = geom::<A>();
    let split_type = if me == 0 { A::undefined() } else { A::comm_type_shared() };
    let mut sub = A::comm_null();
    check_rc!(A::comm_split_type(A::comm_world(), split_type, 0, &mut sub), "split_type");
    if me == 0 {
        check!(sub == A::comm_null(), "UNDEFINED split type yields COMM_NULL");
    } else {
        check!(sub != A::comm_null(), "others get the node comm");
        check_rc!(A::comm_free(&mut sub), "free");
    }
    // A bogus split type must error (not hang, not succeed). Rejected
    // rank-locally before any exchange, so no resync trap.
    check_rc!(A::comm_set_errhandler(A::comm_world(), A::errhandler_return()), "errh");
    let rc = A::comm_split_type(A::comm_world(), -12345, 0, &mut sub);
    check!(rc != 0, "bogus split type errors");
    check_rc!(A::comm_set_errhandler(A::comm_world(), A::errhandler_fatal()), "errh restore");
    check_rc!(A::barrier(A::comm_world()), "resync");
    Ok(())
}

fn compare<A: MpiAbi>(_r: usize) -> Result<(), String> {
    use crate::abi::constants::{MPI_CONGRUENT, MPI_IDENT};
    let mut out = -1;
    check_rc!(A::comm_compare(A::comm_world(), A::comm_world(), &mut out), "compare");
    check!(out == MPI_IDENT, "world vs world is IDENT, got {out}");
    let mut dup = A::comm_null();
    check_rc!(A::comm_dup(A::comm_world(), &mut dup), "dup");
    check_rc!(A::comm_compare(A::comm_world(), dup, &mut out), "compare dup");
    check!(out == MPI_CONGRUENT, "world vs dup is CONGRUENT, got {out}");
    check_rc!(A::comm_free(&mut dup), "free");
    Ok(())
}

fn names<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut name = String::new();
    check_rc!(A::comm_get_name(A::comm_world(), &mut name), "get_name");
    check!(name == "MPI_COMM_WORLD", "default name {name:?}");
    let mut dup = A::comm_null();
    check_rc!(A::comm_dup(A::comm_world(), &mut dup), "dup");
    check_rc!(A::comm_set_name(dup, "workers"), "set_name");
    check_rc!(A::comm_get_name(dup, &mut name), "get_name 2");
    check!(name == "workers", "set name {name:?}");
    check_rc!(A::comm_free(&mut dup), "free");
    Ok(())
}

fn groups<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let mut g = {
        let mut g = unsafe { std::mem::zeroed() };
        check_rc!(A::comm_group(A::comm_world(), &mut g), "comm_group");
        g
    };
    let mut gs = 0;
    check_rc!(A::group_size(g, &mut gs), "group_size");
    check!(gs == n, "group covers world");
    let mut gr = -1;
    check_rc!(A::group_rank(g, &mut gr), "group_rank");
    check!(gr == me, "group rank");
    // Reverse subgroup of min(2, n) members.
    let take = n.min(2);
    let ranks: Vec<i32> = (0..take).rev().collect();
    let mut sub = unsafe { std::mem::zeroed() };
    check_rc!(A::group_incl(g, &ranks, &mut sub), "group_incl");
    let mut ss = 0;
    check_rc!(A::group_size(sub, &mut ss), "sub size");
    check!(ss == take, "sub size {ss}");
    // Translate: sub rank 0 = world rank take-1.
    let mut out = vec![0i32; 1];
    check_rc!(A::group_translate_ranks(sub, &[0], g, &mut out), "translate");
    check!(out[0] == take - 1, "translate: {out:?}");
    check_rc!(A::group_free(&mut sub), "free sub");
    check_rc!(A::group_free(&mut g), "free g");
    Ok(())
}

thread_local! {
    static ERRH_HITS: Cell<i32> = const { Cell::new(0) };
    static ERRH_LAST_CLASS: Cell<i32> = const { Cell::new(0) };
}

fn recording_handler<A: MpiAbi>(_c: A::Comm, code: i32) {
    ERRH_HITS.with(|h| h.set(h.get() + 1));
    ERRH_LAST_CLASS.with(|c| c.set(A::err_class_of(code)));
}

fn errhandler_custom<A: MpiAbi>(_r: usize) -> Result<(), String> {
    ERRH_HITS.with(|h| h.set(0));
    let mut dup = A::comm_null();
    check_rc!(A::comm_dup(A::comm_world(), &mut dup), "dup");
    let mut eh = A::errhandler_return();
    check_rc!(A::comm_create_errhandler(recording_handler::<A>, &mut eh), "create errh");
    check_rc!(A::comm_set_errhandler(dup, eh), "set errh");
    // Trigger: send to an invalid rank.
    let v = [0i32];
    let rc = A::send(slice_ptr(&v), 1, A::datatype(Dt::Int), 12345, 0, dup);
    check!(rc != 0, "invalid rank must error");
    check!(ERRH_HITS.with(|h| h.get()) == 1, "custom handler invoked once");
    check!(
        ERRH_LAST_CLASS.with(|c| c.get()) == crate::abi::errors::MPI_ERR_RANK,
        "handler saw ERR_RANK, got {}",
        ERRH_LAST_CLASS.with(|c| c.get())
    );
    let mut back = A::errhandler_return();
    check_rc!(A::comm_get_errhandler(dup, &mut back), "get errh");
    check!(back == eh, "get returns what was set");
    check_rc!(A::errhandler_free(&mut eh), "free errh");
    check_rc!(A::comm_free(&mut dup), "free comm");
    check_rc!(A::barrier(A::comm_world()), "resync");
    Ok(())
}

fn attributes<A: MpiAbi>(_r: usize) -> Result<(), String> {
    // Predefined TAG_UB.
    let mut v = 0usize;
    let mut flag = false;
    check_rc!(
        A::comm_get_attr(A::comm_world(), crate::abi::constants::MPI_TAG_UB, &mut v, &mut flag),
        "get TAG_UB"
    );
    check!(flag, "TAG_UB present");
    check!(v >= 32767, "TAG_UB at least 32767: {v}");
    // User keyval.
    let mut kv = 0;
    check_rc!(A::comm_create_keyval(None, None, 0, &mut kv), "create_keyval");
    check_rc!(A::comm_set_attr(A::comm_world(), kv, 0xBEEF), "set_attr");
    check_rc!(A::comm_get_attr(A::comm_world(), kv, &mut v, &mut flag), "get_attr");
    check!(flag && v == 0xBEEF, "attr roundtrip: {v:#x}");
    check_rc!(A::comm_delete_attr(A::comm_world(), kv), "delete_attr");
    check_rc!(A::comm_get_attr(A::comm_world(), kv, &mut v, &mut flag), "get after delete");
    check!(!flag, "attr gone");
    check_rc!(A::comm_free_keyval(&mut kv), "free_keyval");
    Ok(())
}

thread_local! {
    static COPIES: Cell<i32> = const { Cell::new(0) };
    static DELETES: Cell<i32> = const { Cell::new(0) };
}

fn counting_copy<A: MpiAbi>(_c: A::Comm, _kv: i32, extra: usize, val: usize) -> (bool, usize) {
    COPIES.with(|c| c.set(c.get() + 1));
    (true, val + extra)
}

fn counting_delete<A: MpiAbi>(_c: A::Comm, _kv: i32, _extra: usize, _val: usize) {
    DELETES.with(|c| c.set(c.get() + 1));
}

fn attr_callbacks_on_dup<A: MpiAbi>(_r: usize) -> Result<(), String> {
    COPIES.with(|c| c.set(0));
    DELETES.with(|c| c.set(0));
    let mut kv = 0;
    check_rc!(
        A::comm_create_keyval(Some(counting_copy::<A>), Some(counting_delete::<A>), 5, &mut kv),
        "create_keyval"
    );
    let mut base = A::comm_null();
    check_rc!(A::comm_dup(A::comm_world(), &mut base), "dup base");
    check_rc!(A::comm_set_attr(base, kv, 100), "set");
    let mut copy = A::comm_null();
    check_rc!(A::comm_dup(base, &mut copy), "dup copy");
    check!(COPIES.with(|c| c.get()) == 1, "copy callback ran");
    let mut v = 0usize;
    let mut flag = false;
    check_rc!(A::comm_get_attr(copy, kv, &mut v, &mut flag), "get on copy");
    check!(flag && v == 105, "copied value transformed by extra_state: {v}");
    check_rc!(A::comm_free(&mut copy), "free copy");
    check!(DELETES.with(|c| c.get()) == 1, "delete ran on freed copy");
    check_rc!(A::comm_free(&mut base), "free base");
    check!(DELETES.with(|c| c.get()) == 2, "delete ran on freed base");
    check_rc!(A::comm_free_keyval(&mut kv), "free keyval");
    Ok(())
}

fn info<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut i = A::info_null();
    check_rc!(A::info_create(&mut i), "info_create");
    check_rc!(A::info_set(i, "io_strategy", "collective"), "info_set");
    check_rc!(A::info_set(i, "cb_nodes", "4"), "info_set 2");
    let mut v = String::new();
    let mut flag = false;
    check_rc!(A::info_get(i, "io_strategy", &mut v, &mut flag), "info_get");
    check!(flag && v == "collective", "info roundtrip {v:?}");
    check_rc!(A::info_get(i, "missing", &mut v, &mut flag), "info_get missing");
    check!(!flag, "missing key flag false");
    check_rc!(A::info_free(&mut i), "info_free");
    Ok(())
}
