"""AOT compile path: lower the L2/L1 graphs to HLO **text** artifacts
the Rust runtime loads via PJRT.

Text, not ``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids, which xla_extension 0.5.1 (the version behind the
published ``xla`` crate) rejects (``proto.id() <= INT_MAX``). The HLO
text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Run once at build time (``make artifacts``); Python never appears on the
request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import reduce as kreduce


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Reduction artifact sizes (f32 elements). The Rust op engine dispatches
# exact matches to XLA and falls back to the scalar loop otherwise.
REDUCE_SIZES = (4096, 65536, 1048576)


def lower_all():
    """Yield (name, lowered, meta) for every artifact."""
    # Elementwise reduction kernels.
    for op in kreduce.OPS:
        for n in REDUCE_SIZES:
            spec = jax.ShapeDtypeStruct((n,), jnp.float32)

            def fn(a, b, _op=op):
                return (kreduce.reduce_op(a, b, op=_op),)

            lowered = jax.jit(fn).lower(spec, spec)
            yield (
                f"reduce_{op}_f32_{n}",
                lowered,
                {"inputs": [["f32", [n]], ["f32", [n]]], "outputs": [["f32", [n]]]},
            )

    # Training step + optimizer.
    args = model.example_args_grad_step()
    lowered = jax.jit(model.grad_step).lower(*args)
    meta = {
        "inputs": [["f32", list(a.shape)] for a in args],
        "outputs": [["f32", []]]
        + [["f32", list(a.shape)] for a in args[:4]],
    }
    yield ("grad_step", lowered, meta)

    args = model.example_args_sgd_update()
    lowered = jax.jit(model.sgd_update).lower(*args)
    meta = {
        "inputs": [["f32", list(getattr(a, "shape", []))] for a in args],
        "outputs": [["f32", list(a.shape)] for a in args[:4]],
    }
    yield ("sgd_update", lowered, meta)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    for name, lowered, meta in lower_all():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = meta
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
