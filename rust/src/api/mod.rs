//! The MPI **API** surface, abstracted over ABIs.
//!
//! MPI is standardized as an API: the same *source* compiles against any
//! implementation, but each implementation's binary representation of
//! handles/statuses/constants differs — that is the paper's entire
//! problem statement. We model "recompiling the same source against a
//! different mpi.h" with a trait: [`MpiAbi`]'s associated types are the
//! opaque handles, associated functions return the predefined constants
//! (functions, not consts, because Open-MPI-style constants are
//! link-time addresses, §3.3), and generic code (the test suite, the OSU
//! benchmarks, the examples) is monomorphized per ABI exactly as C code
//! is recompiled per mpi.h.
//!
//! Callback registration uses plain `fn` pointers (as in C) — forcing
//! translation layers into the trampoline/state-map machinery the paper
//! describes (§6.2), rather than letting Rust closures smuggle state.

/// Canonical names for the predefined datatypes the portable surface
/// exposes (each ABI maps them to its own handle representation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dt {
    Int,
    Float,
    Double,
    Byte,
    Char,
    Short,
    UInt16,
    Int32,
    Int64,
    UInt64,
    Aint,
    FloatInt,
    TwoInt,
}

/// Canonical names for the predefined reduction ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpName {
    Sum,
    Min,
    Max,
    Prod,
    Band,
    Bor,
    Bxor,
    Land,
    Lor,
    Lxor,
    Minloc,
    Maxloc,
}

/// User reduction function in ABI `A`: `(invec, inoutvec, len, datatype)`.
pub type UserOpFn<A> = fn(*const u8, *mut u8, i32, <A as MpiAbi>::Datatype);

/// Attribute copy callback: `(comm, keyval, extra_state, value) ->
/// (flag, new_value)`.
pub type AttrCopyFn<A> = fn(<A as MpiAbi>::Comm, i32, usize, usize) -> (bool, usize);

/// Attribute delete callback.
pub type AttrDeleteFn<A> = fn(<A as MpiAbi>::Comm, i32, usize, usize);

/// Error-handler callback: `(comm, error_code)`.
pub type ErrhFn<A> = fn(<A as MpiAbi>::Comm, i32);

/// An MPI ABI: the binary surface one compiles against.
///
/// Every method returns the ABI's own `int` error code (0 = success in
/// every known ABI; other values differ and must be translated by layers
/// like Mukautuva). Output parameters are `&mut` in Rust style.
#[allow(clippy::too_many_arguments)]
pub trait MpiAbi: 'static {
    /// Human name for reports ("mpich", "ompi", "muk(mpich)", "abi").
    const NAME: &'static str;

    type Comm: Copy + PartialEq + std::fmt::Debug;
    type Datatype: Copy + PartialEq + std::fmt::Debug;
    type Op: Copy + PartialEq;
    type Request: Copy + PartialEq + std::fmt::Debug;
    type Group: Copy + PartialEq;
    type Errhandler: Copy + PartialEq;
    type Info: Copy + PartialEq;
    /// `MPI_Win` — the RMA window handle (in the paper's handle table
    /// alongside `MPI_Comm` and `MPI_Request`).
    type Win: Copy + PartialEq + std::fmt::Debug;
    /// The ABI's status struct (layouts differ! §3.2).
    type Status: Copy;

    // --- Predefined constants (functions: OMPI-style constants are
    // link-time addresses, not compile-time constants) ---
    fn comm_world() -> Self::Comm;
    fn comm_self() -> Self::Comm;
    fn comm_null() -> Self::Comm;
    fn request_null() -> Self::Request;
    fn datatype(d: Dt) -> Self::Datatype;
    fn op(o: OpName) -> Self::Op;
    fn errhandler_return() -> Self::Errhandler;
    fn errhandler_fatal() -> Self::Errhandler;
    fn info_null() -> Self::Info;
    fn win_null() -> Self::Win;

    /// Special integer constants — ABIs number these differently.
    fn any_source() -> i32;
    fn any_tag() -> i32;
    fn proc_null() -> i32;
    fn undefined() -> i32;
    /// The `MPI_IN_PLACE` buffer sentinel.
    fn in_place() -> *const u8;
    /// `MPI_LOCK_EXCLUSIVE` — implementations number lock types
    /// differently (MPICH: 234, Open MPI: 1), §5.4.
    fn lock_exclusive() -> i32;
    /// `MPI_LOCK_SHARED`.
    fn lock_shared() -> i32;
    /// `MPI_MODE_NOCHECK` (window assertion bit; OMPI numbers the whole
    /// family differently from MPICH and the standard ABI).
    fn mode_nocheck() -> i32;
    /// `MPI_MODE_NOSTORE`.
    fn mode_nostore() -> i32;
    /// `MPI_MODE_NOPUT`.
    fn mode_noput() -> i32;
    /// `MPI_MODE_NOPRECEDE`.
    fn mode_noprecede() -> i32;
    /// `MPI_MODE_NOSUCCEED`.
    fn mode_nosucceed() -> i32;

    /// Success / canonical error classes in this ABI's numbering.
    fn err_class_of(code: i32) -> i32;
    fn error_string(code: i32) -> String;
    /// This ABI's numeric value for a canonical (standard-ABI) class.
    fn err_from_canonical(class: i32) -> i32;

    // --- Environment ---
    fn init() -> i32;
    fn finalize() -> i32;
    fn initialized() -> bool;
    fn finalized() -> bool;
    fn abort(comm: Self::Comm, code: i32) -> i32;
    fn wtime() -> f64;
    fn get_library_version() -> String;
    fn get_version() -> (i32, i32);
    fn get_processor_name() -> String;

    // --- Status accessors (layouts differ per ABI) ---
    fn status_empty() -> Self::Status;
    fn status_source(s: &Self::Status) -> i32;
    fn status_tag(s: &Self::Status) -> i32;
    fn status_error(s: &Self::Status) -> i32;
    fn status_cancelled(s: &Self::Status) -> bool;
    fn get_count(s: &Self::Status, dt: Self::Datatype) -> i32;
    /// `MPI_Get_elements`: basic-element count of the received data —
    /// unlike `get_count` it resolves partial items of a derived type
    /// down to their basic leaves.
    fn get_elements(s: &Self::Status, dt: Self::Datatype) -> i32;

    // --- Communicators & groups ---
    fn comm_size(c: Self::Comm, out: &mut i32) -> i32;
    fn comm_rank(c: Self::Comm, out: &mut i32) -> i32;
    fn comm_dup(c: Self::Comm, out: &mut Self::Comm) -> i32;
    fn comm_split(c: Self::Comm, color: i32, key: i32, out: &mut Self::Comm) -> i32;
    fn comm_free(c: &mut Self::Comm) -> i32;
    fn comm_compare(a: Self::Comm, b: Self::Comm, out: &mut i32) -> i32;
    fn comm_set_name(c: Self::Comm, name: &str) -> i32;
    fn comm_get_name(c: Self::Comm, out: &mut String) -> i32;
    fn comm_group(c: Self::Comm, out: &mut Self::Group) -> i32;
    fn group_size(g: Self::Group, out: &mut i32) -> i32;
    fn group_rank(g: Self::Group, out: &mut i32) -> i32;
    fn group_incl(g: Self::Group, ranks: &[i32], out: &mut Self::Group) -> i32;
    fn group_translate_ranks(
        a: Self::Group,
        ranks: &[i32],
        b: Self::Group,
        out: &mut [i32],
    ) -> i32;
    fn group_free(g: &mut Self::Group) -> i32;
    fn comm_set_errhandler(c: Self::Comm, e: Self::Errhandler) -> i32;
    fn comm_get_errhandler(c: Self::Comm, out: &mut Self::Errhandler) -> i32;
    fn comm_create_errhandler(f: ErrhFn<Self>, out: &mut Self::Errhandler) -> i32;
    fn errhandler_free(e: &mut Self::Errhandler) -> i32;

    // --- Point-to-point ---
    fn send(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
    ) -> i32;
    fn ssend(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
    ) -> i32;
    fn recv(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        src: i32,
        tag: i32,
        comm: Self::Comm,
        status: &mut Self::Status,
    ) -> i32;
    fn isend(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn issend(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn irecv(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        src: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn wait(req: &mut Self::Request, status: &mut Self::Status) -> i32;
    fn test(req: &mut Self::Request, flag: &mut bool, status: &mut Self::Status) -> i32;
    fn waitall(reqs: &mut [Self::Request], statuses: &mut [Self::Status]) -> i32;
    fn testall(reqs: &mut [Self::Request], flag: &mut bool, statuses: &mut [Self::Status]) -> i32;
    fn waitany(reqs: &mut [Self::Request], index: &mut i32, status: &mut Self::Status) -> i32;
    /// `MPI_Testany` (§3.7.5): on return, `flag && index >= 0` means that
    /// request completed; `flag && index == MPI_UNDEFINED` means no
    /// active request exists in the list; `!flag` means none is done yet.
    fn testany(
        reqs: &mut [Self::Request],
        index: &mut i32,
        flag: &mut bool,
        status: &mut Self::Status,
    ) -> i32;
    /// `MPI_Waitsome`: blocks until ≥ 1 active request completes;
    /// `indices[..outcount]` name the completed slots (with their
    /// statuses in `statuses[..outcount]`). `outcount = MPI_UNDEFINED`
    /// when the list holds no active request. Inactive persistent
    /// requests are ignored, as in `waitany`.
    fn waitsome(
        reqs: &mut [Self::Request],
        outcount: &mut i32,
        indices: &mut [i32],
        statuses: &mut [Self::Status],
    ) -> i32;
    /// `MPI_Testsome`: like `waitsome` but never blocks — `outcount` may
    /// be 0 when active requests exist and none has completed.
    fn testsome(
        reqs: &mut [Self::Request],
        outcount: &mut i32,
        indices: &mut [i32],
        statuses: &mut [Self::Status],
    ) -> i32;
    fn probe(src: i32, tag: i32, comm: Self::Comm, status: &mut Self::Status) -> i32;
    fn iprobe(
        src: i32,
        tag: i32,
        comm: Self::Comm,
        flag: &mut bool,
        status: &mut Self::Status,
    ) -> i32;
    fn cancel(req: &mut Self::Request) -> i32;
    fn request_free(req: &mut Self::Request) -> i32;
    fn sendrecv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        dest: i32,
        sendtag: i32,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        src: i32,
        recvtag: i32,
        comm: Self::Comm,
        status: &mut Self::Status,
    ) -> i32;

    // --- Persistent point-to-point (MPI_Send_init / MPI_Recv_init) ---
    //
    // `*_init` returns an **inactive** request that `start`/`startall`
    // re-arm any number of times; wait/test return it to inactive
    // instead of freeing it, and the handle stays valid (it only becomes
    // REQUEST_NULL through `request_free`, legal while inactive). The
    // lifecycle must behave identically across ABIs — it is part of the
    // binary contract the paper standardizes.
    fn send_init(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn ssend_init(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn recv_init(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        src: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn start(req: &mut Self::Request) -> i32;
    fn startall(reqs: &mut [Self::Request]) -> i32;

    // --- Datatypes ---
    fn type_size(dt: Self::Datatype, out: &mut i32) -> i32;
    fn type_get_extent(dt: Self::Datatype, lb: &mut isize, extent: &mut isize) -> i32;
    fn type_contiguous(count: i32, child: Self::Datatype, out: &mut Self::Datatype) -> i32;
    fn type_vector(
        count: i32,
        blocklen: i32,
        stride: i32,
        child: Self::Datatype,
        out: &mut Self::Datatype,
    ) -> i32;
    fn type_create_struct(
        blocks: &[(i32, isize, Self::Datatype)],
        out: &mut Self::Datatype,
    ) -> i32;
    fn type_commit(dt: &mut Self::Datatype) -> i32;
    fn type_free(dt: &mut Self::Datatype) -> i32;
    fn type_dup(dt: Self::Datatype, out: &mut Self::Datatype) -> i32;

    // --- Reduction ops ---
    fn op_create(f: UserOpFn<Self>, commute: bool, out: &mut Self::Op) -> i32;
    fn op_free(op: &mut Self::Op) -> i32;

    // --- Collectives ---
    fn barrier(comm: Self::Comm) -> i32;
    fn bcast(buf: *mut u8, count: i32, dt: Self::Datatype, root: i32, comm: Self::Comm) -> i32;
    fn reduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        root: i32,
        comm: Self::Comm,
    ) -> i32;
    fn allreduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
    ) -> i32;
    fn gather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
    ) -> i32;
    fn scatter(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
    ) -> i32;
    fn allgather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
    ) -> i32;
    fn alltoall(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
    ) -> i32;
    fn alltoallw(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtypes: &[Self::Datatype],
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtypes: &[Self::Datatype],
        comm: Self::Comm,
    ) -> i32;
    fn ialltoallw(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtypes: &[Self::Datatype],
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtypes: &[Self::Datatype],
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn scan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
    ) -> i32;
    fn exscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
    ) -> i32;
    fn reduce_scatter_block(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        recvcount: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
    ) -> i32;

    // --- Nonblocking collectives (MPI 3.x) ---
    //
    // Every operation returns a request handle in this ABI's
    // representation; translation layers must convert it and keep any
    // per-call temporary state alive until completion (§6.2) — the
    // heaviest handle traffic in the API, which is why the benches
    // measure exactly these paths.
    fn ibarrier(comm: Self::Comm, req: &mut Self::Request) -> i32;
    fn ibcast(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn ireduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn iallreduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn igather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn igatherv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        displs: &[i32],
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn iscatter(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn iscatterv(
        sendbuf: *const u8,
        sendcounts: &[i32],
        displs: &[i32],
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn iallgather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn iallgatherv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        displs: &[i32],
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn ialltoall(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn ialltoallv(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn iscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn iexscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn ireduce_scatter_block(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        recvcount: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;

    // --- Persistent collectives (MPI-4) ---
    //
    // Collective calls: every rank of `comm` must create the same
    // persistent collectives in the same order (they agree on a tag
    // plane at init time). Starts re-read the user buffers; the
    // schedule built at init is reused, never rebuilt.
    fn barrier_init(comm: Self::Comm, req: &mut Self::Request) -> i32;
    fn bcast_init(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn allreduce_init(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn gather_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn scatter_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    fn alltoall_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;

    // --- One-sided communication (RMA) ---
    //
    // `MPI_Win` is a first-class opaque handle: every layer represents
    // it its own way (int with T_WIN bits, pointer-to-descriptor,
    // zero-page word) and the translation layer round-trips it through
    // the word union like any other handle. Displacements are `MPI_Aint`
    // (§5.1) and assertion/lock-type constants differ per ABI (§5.4) —
    // use the `mode_*`/`lock_*` constant functions above.
    fn win_create(
        base: *mut u8,
        size: crate::abi::types::Aint,
        disp_unit: i32,
        info: Self::Info,
        comm: Self::Comm,
        win: &mut Self::Win,
    ) -> i32;
    fn win_allocate(
        size: crate::abi::types::Aint,
        disp_unit: i32,
        info: Self::Info,
        comm: Self::Comm,
        baseptr: &mut *mut u8,
        win: &mut Self::Win,
    ) -> i32;
    fn win_free(win: &mut Self::Win) -> i32;
    fn win_fence(assert: i32, win: Self::Win) -> i32;
    fn win_lock(lock_type: i32, rank: i32, assert: i32, win: Self::Win) -> i32;
    fn win_unlock(rank: i32, win: Self::Win) -> i32;
    fn win_flush(rank: i32, win: Self::Win) -> i32;
    fn put(
        origin: *const u8,
        origin_count: i32,
        origin_dt: Self::Datatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: Self::Datatype,
        win: Self::Win,
    ) -> i32;
    fn get(
        origin: *mut u8,
        origin_count: i32,
        origin_dt: Self::Datatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: Self::Datatype,
        win: Self::Win,
    ) -> i32;
    fn accumulate(
        origin: *const u8,
        origin_count: i32,
        origin_dt: Self::Datatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: Self::Datatype,
        op: Self::Op,
        win: Self::Win,
    ) -> i32;
    /// `MPI_Get_address`: identical arithmetic in every ABI, but part of
    /// the binary surface because `MPI_Aint`'s width is pinned by §5.1.
    fn get_address(location: *const u8, out: &mut crate::abi::types::Aint) -> i32 {
        *out = location as crate::abi::types::Aint;
        0
    }
    /// `MPI_Aint_add` (MPI 3.1 §4.1.5: wraps like pointer arithmetic).
    fn aint_add(base: crate::abi::types::Aint, disp: crate::abi::types::Aint)
        -> crate::abi::types::Aint {
        base.wrapping_add(disp)
    }
    /// `MPI_Aint_diff`.
    fn aint_diff(addr1: crate::abi::types::Aint, addr2: crate::abi::types::Aint)
        -> crate::abi::types::Aint {
        addr1.wrapping_sub(addr2)
    }

    // --- Attributes ---
    fn comm_create_keyval(
        copy: Option<AttrCopyFn<Self>>,
        delete: Option<AttrDeleteFn<Self>>,
        extra_state: usize,
        out: &mut i32,
    ) -> i32;
    fn comm_free_keyval(keyval: &mut i32) -> i32;
    fn comm_set_attr(c: Self::Comm, keyval: i32, value: usize) -> i32;
    fn comm_get_attr(c: Self::Comm, keyval: i32, value: &mut usize, flag: &mut bool) -> i32;
    fn comm_delete_attr(c: Self::Comm, keyval: i32) -> i32;

    // --- Info ---
    fn info_create(out: &mut Self::Info) -> i32;
    fn info_set(i: Self::Info, key: &str, value: &str) -> i32;
    fn info_get(i: Self::Info, key: &str, out: &mut String, flag: &mut bool) -> i32;
    fn info_free(i: &mut Self::Info) -> i32;
}

/// Map a canonical [`Dt`] to the standard-ABI datatype constant.
pub fn dt_to_abi_const(d: Dt) -> usize {
    use crate::abi::datatypes as adt;
    match d {
        Dt::Int => adt::MPI_INT,
        Dt::Float => adt::MPI_FLOAT,
        Dt::Double => adt::MPI_DOUBLE,
        Dt::Byte => adt::MPI_BYTE,
        Dt::Char => adt::MPI_CHAR,
        Dt::Short => adt::MPI_SHORT,
        Dt::UInt16 => adt::MPI_UINT16_T,
        Dt::Int32 => adt::MPI_INT32_T,
        Dt::Int64 => adt::MPI_INT64_T,
        Dt::UInt64 => adt::MPI_UINT64_T,
        Dt::Aint => adt::MPI_AINT,
        Dt::FloatInt => adt::MPI_FLOAT_INT,
        Dt::TwoInt => adt::MPI_2INT,
    }
}

/// Map a canonical [`OpName`] to the standard-ABI op constant.
pub fn op_to_abi_const(o: OpName) -> usize {
    use crate::abi::ops as aop;
    match o {
        OpName::Sum => aop::MPI_SUM,
        OpName::Min => aop::MPI_MIN,
        OpName::Max => aop::MPI_MAX,
        OpName::Prod => aop::MPI_PROD,
        OpName::Band => aop::MPI_BAND,
        OpName::Bor => aop::MPI_BOR,
        OpName::Bxor => aop::MPI_BXOR,
        OpName::Land => aop::MPI_LAND,
        OpName::Lor => aop::MPI_LOR,
        OpName::Lxor => aop::MPI_LXOR,
        OpName::Minloc => aop::MPI_MINLOC,
        OpName::Maxloc => aop::MPI_MAXLOC,
    }
}
