//! The proposed **standard MPI ABI** (the paper's §5 and Appendix A).
//!
//! This module is the normative artifact of the reproduction: the ABI is a
//! *binary* contract, so everything here is specified in terms of exact bit
//! patterns, byte sizes and alignments, not Rust abstractions.
//!
//! Contents:
//! - [`types`] — the MPI integer types (`MPI_Aint`, `MPI_Offset`,
//!   `MPI_Count`, `MPI_Fint`) and the `AnOm` ABI-variant notation (§5.1).
//! - [`status`] — the 32-byte standard status object (§5.2).
//! - [`handles`] — word-sized opaque handle newtypes modelling the
//!   incomplete-struct-pointer design (§5.3).
//! - [`huffman`] — the 10-bit modified Huffman code for predefined handle
//!   constants (§5.4, Appendix A), including the fast datatype-size and
//!   handle-kind bit decoders.
//! - [`ops`] / [`datatypes`] — the predefined constant values (A.1 / A.3).
//! - [`constants`] — integer constants: unique negatives, XOR-combinable
//!   powers of two, string lengths, predefined callbacks (§5.4).
//! - [`errors`] — error classes with `MPI_SUCCESS == 0`.

// The ABI is a normative artifact: every public item is part of the
// binary contract and must say what it pins down.
#![warn(missing_docs)]

pub mod constants;
pub mod datatypes;
pub mod errors;
pub mod handles;
pub mod huffman;
pub mod ops;
pub mod status;
pub mod types;

pub use constants::*;
pub use datatypes::*;
pub use errors::*;
pub use handles::*;
pub use huffman::{decode, is_zero_page, HandleKind};
pub use ops::*;
pub use status::AbiStatus;
pub use types::{AbiVariant, Aint, Count, Fint, Offset};
