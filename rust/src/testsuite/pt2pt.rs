//! Point-to-point tests.

use super::util::*;
use super::TestFn;
use crate::api::{Dt, MpiAbi};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("pt2pt.ring", ring::<A>),
        ("pt2pt.wildcards", wildcards::<A>),
        ("pt2pt.isend_waitall_window", isend_waitall_window::<A>),
        ("pt2pt.ssend", ssend::<A>),
        ("pt2pt.sendrecv_rotate", sendrecv_rotate::<A>),
        ("pt2pt.probe_get_count", probe_get_count::<A>),
        ("pt2pt.iprobe_polling", iprobe_polling::<A>),
        ("pt2pt.truncation_error", truncation_error::<A>),
        ("pt2pt.cancel_recv", cancel_recv::<A>),
        ("pt2pt.large_message", large_message::<A>),
        ("pt2pt.proc_null", proc_null::<A>),
        ("pt2pt.tag_selectivity", tag_selectivity::<A>),
        ("pt2pt.waitany_first", waitany_first::<A>),
        ("pt2pt.testany_polls", testany_polls::<A>),
        ("pt2pt.waitsome_batch", waitsome_batch::<A>),
        ("pt2pt.testsome_drains", testsome_drains::<A>),
    ]
}

fn world_geometry<A: MpiAbi>() -> (i32, i32) {
    let (mut size, mut rank) = (0, 0);
    A::comm_size(A::comm_world(), &mut size);
    A::comm_rank(A::comm_world(), &mut rank);
    (size, rank)
}

fn ring<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let next = (me + 1) % n;
    let prev = (me + n - 1) % n;
    let token = [me * 7 + 1];
    let mut got = [0i32];
    let mut st = A::status_empty();
    if me == 0 {
        check_rc!(A::send(slice_ptr(&token), 1, dt, next, 3, A::comm_world()), "send");
        check_rc!(
            A::recv(slice_ptr_mut(&mut got), 1, dt, prev, 3, A::comm_world(), &mut st),
            "recv"
        );
    } else {
        check_rc!(
            A::recv(slice_ptr_mut(&mut got), 1, dt, prev, 3, A::comm_world(), &mut st),
            "recv"
        );
        check_rc!(A::send(slice_ptr(&token), 1, dt, next, 3, A::comm_world()), "send");
    }
    check!(got[0] == prev * 7 + 1, "ring value from {prev}: got {}", got[0]);
    check!(A::status_source(&st) == prev, "status source");
    check!(A::status_tag(&st) == 3, "status tag");
    Ok(())
}

fn wildcards<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int);
    if me == 0 {
        let mut seen = vec![false; n as usize];
        for _ in 1..n {
            let mut v = [0i32];
            let mut st = A::status_empty();
            check_rc!(
                A::recv(slice_ptr_mut(&mut v), 1, dt, A::any_source(), A::any_tag(),
                    A::comm_world(), &mut st),
                "wildcard recv"
            );
            let src = A::status_source(&st);
            check!(src >= 1 && src < n, "source in range: {src}");
            check!(v[0] == src * 100, "payload matches source");
            check!(A::status_tag(&st) == src, "tag came through");
            check!(!seen[src as usize], "no duplicate source");
            seen[src as usize] = true;
        }
    } else {
        let v = [me * 100];
        check_rc!(A::send(slice_ptr(&v), 1, dt, 0, me, A::comm_world()), "send");
    }
    Ok(())
}

fn isend_waitall_window<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    const WINDOW: usize = 32;
    let dt = A::datatype(Dt::Int64);
    if me == 0 {
        let bufs: Vec<[i64; 1]> = (0..WINDOW).map(|i| [i as i64 * 3]).collect();
        let mut reqs = vec![A::request_null(); WINDOW];
        for i in 0..WINDOW {
            check_rc!(
                A::isend(slice_ptr(&bufs[i]), 1, dt, 1, i as i32, A::comm_world(), &mut reqs[i]),
                "isend"
            );
        }
        let mut sts = vec![A::status_empty(); WINDOW];
        check_rc!(A::waitall(&mut reqs, &mut sts), "waitall");
        for r in &reqs {
            check!(*r == A::request_null(), "requests reset to null");
        }
    } else if me == 1 {
        let mut bufs: Vec<[i64; 1]> = vec![[0]; WINDOW];
        let mut reqs = vec![A::request_null(); WINDOW];
        for (i, b) in bufs.iter_mut().enumerate() {
            check_rc!(
                A::irecv(slice_ptr_mut(b), 1, dt, 0, i as i32, A::comm_world(), &mut reqs[i]),
                "irecv"
            );
        }
        let mut sts = vec![A::status_empty(); WINDOW];
        check_rc!(A::waitall(&mut reqs, &mut sts), "waitall");
        for (i, b) in bufs.iter().enumerate() {
            check!(b[0] == i as i64 * 3, "window payload {i}");
            check!(A::status_tag(&sts[i]) == i as i32, "window status tag {i}");
        }
    }
    Ok(())
}

fn ssend<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Double);
    if me == 0 {
        let v = [42.5f64];
        check_rc!(A::ssend(slice_ptr(&v), 1, dt, 1, 9, A::comm_world()), "ssend");
    } else if me == 1 {
        let mut v = [0.0f64];
        let mut st = A::status_empty();
        check_rc!(A::recv(slice_ptr_mut(&mut v), 1, dt, 0, 9, A::comm_world(), &mut st), "recv");
        check!(v[0] == 42.5, "ssend payload");
    }
    Ok(())
}

fn sendrecv_rotate<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int);
    let right = (me + 1) % n;
    let left = (me + n - 1) % n;
    let send = [me];
    let mut recv = [-1];
    let mut st = A::status_empty();
    check_rc!(
        A::sendrecv(slice_ptr(&send), 1, dt, right, 5, slice_ptr_mut(&mut recv), 1, dt, left, 5,
            A::comm_world(), &mut st),
        "sendrecv"
    );
    check!(recv[0] == left, "rotated value");
    Ok(())
}

fn probe_get_count<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Short);
    if me == 0 {
        let v = [1i16, 2, 3, 4, 5];
        check_rc!(A::send(slice_ptr(&v), 5, dt, 1, 11, A::comm_world()), "send");
    } else if me == 1 {
        let mut st = A::status_empty();
        check_rc!(A::probe(0, 11, A::comm_world(), &mut st), "probe");
        let count = A::get_count(&st, dt);
        check!(count == 5, "probed count = {count}, want 5");
        let mut v = [0i16; 5];
        check_rc!(A::recv(slice_ptr_mut(&mut v), 5, dt, 0, 11, A::comm_world(), &mut st), "recv");
        check!(v == [1, 2, 3, 4, 5], "payload");
        check!(A::get_count(&st, dt) == 5, "recv status count");
    }
    Ok(())
}

fn iprobe_polling<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Byte);
    if me == 0 {
        let v = [0xABu8];
        check_rc!(A::send(slice_ptr(&v), 1, dt, 1, 2, A::comm_world()), "send");
    } else if me == 1 {
        let mut flag = false;
        let mut st = A::status_empty();
        let mut spins = 0u64;
        while !flag {
            check_rc!(A::iprobe(0, 2, A::comm_world(), &mut flag, &mut st), "iprobe");
            spins += 1;
            if spins > 50_000_000 {
                return Err("iprobe never saw the message".to_string());
            }
        }
        let mut v = [0u8];
        check_rc!(A::recv(slice_ptr_mut(&mut v), 1, dt, 0, 2, A::comm_world(), &mut st), "recv");
        check!(v[0] == 0xAB, "payload");
    }
    Ok(())
}

fn truncation_error<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    // Errors must be returned, not fatal, for this test.
    check_rc!(A::comm_set_errhandler(A::comm_world(), A::errhandler_return()), "set errh");
    let dt = A::datatype(Dt::Int);
    if me == 0 {
        let v = [1i32, 2, 3, 4];
        check_rc!(A::send(slice_ptr(&v), 4, dt, 1, 8, A::comm_world()), "send");
    } else if me == 1 {
        let mut v = [0i32; 2];
        let mut st = A::status_empty();
        let rc = A::recv(slice_ptr_mut(&mut v), 2, dt, 0, 8, A::comm_world(), &mut st);
        check!(rc != 0, "truncated recv must fail");
        check!(
            A::err_class_of(rc) == crate::abi::errors::MPI_ERR_TRUNCATE,
            "class is TRUNCATE (got {})",
            A::err_class_of(rc)
        );
    }
    check_rc!(A::comm_set_errhandler(A::comm_world(), A::errhandler_fatal()), "restore errh");
    // Resynchronize before the next test.
    check_rc!(A::barrier(A::comm_world()), "barrier");
    Ok(())
}

fn cancel_recv<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let dt = A::datatype(Dt::Int);
    let mut v = [0i32];
    let mut req = A::request_null();
    // Post a recv that can never match (tag nobody sends).
    check_rc!(
        A::irecv(slice_ptr_mut(&mut v), 1, dt, A::any_source(), 31000, A::comm_world(), &mut req),
        "irecv"
    );
    check_rc!(A::cancel(&mut req), "cancel");
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req, &mut st), "wait after cancel");
    check!(A::status_cancelled(&st), "status must say cancelled");
    Ok(())
}

fn large_message<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    const COUNT: usize = 64 * 1024; // 256 KiB of i32: heap payload path
    let dt = A::datatype(Dt::Int32);
    if me == 0 {
        let v: Vec<i32> = (0..COUNT as i32).collect();
        check_rc!(A::send(slice_ptr(&v), COUNT as i32, dt, 1, 1, A::comm_world()), "send");
    } else if me == 1 {
        let mut v = vec![0i32; COUNT];
        let mut st = A::status_empty();
        check_rc!(
            A::recv(slice_ptr_mut(&mut v), COUNT as i32, dt, 0, 1, A::comm_world(), &mut st),
            "recv"
        );
        check!(A::get_count(&st, dt) == COUNT as i32, "count");
        for (i, &x) in v.iter().enumerate().step_by(4096) {
            check!(x == i as i32, "content at {i}");
        }
        check!(v[COUNT - 1] == COUNT as i32 - 1, "last element");
    }
    Ok(())
}

fn proc_null<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let dt = A::datatype(Dt::Int);
    let v = [1i32];
    // Send/recv to PROC_NULL complete immediately.
    check_rc!(A::send(slice_ptr(&v), 1, dt, A::proc_null(), 0, A::comm_world()), "send to null");
    let mut b = [9i32];
    let mut st = A::status_empty();
    check_rc!(
        A::recv(slice_ptr_mut(&mut b), 1, dt, A::proc_null(), 0, A::comm_world(), &mut st),
        "recv from null"
    );
    check!(b[0] == 9, "buffer untouched");
    check!(A::status_source(&st) == A::proc_null(), "status source is PROC_NULL");
    Ok(())
}

fn tag_selectivity<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int);
    if me == 0 {
        // Send tag 1 then tag 2; receiver takes tag 2 first.
        let a = [111i32];
        let b = [222i32];
        check_rc!(A::send(slice_ptr(&a), 1, dt, 1, 1, A::comm_world()), "send 1");
        check_rc!(A::send(slice_ptr(&b), 1, dt, 1, 2, A::comm_world()), "send 2");
    } else if me == 1 {
        let mut v = [0i32];
        let mut st = A::status_empty();
        check_rc!(A::recv(slice_ptr_mut(&mut v), 1, dt, 0, 2, A::comm_world(), &mut st), "recv 2");
        check!(v[0] == 222, "tag-2 message first");
        check_rc!(A::recv(slice_ptr_mut(&mut v), 1, dt, 0, 1, A::comm_world(), &mut st), "recv 1");
        check!(v[0] == 111, "then tag-1");
    }
    Ok(())
}

fn waitany_first<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int);
    if me == 0 {
        let v = [5i32];
        check_rc!(A::send(slice_ptr(&v), 1, dt, 1, 21, A::comm_world()), "send");
    } else if me == 1 {
        let mut a = [0i32];
        let mut b = [0i32];
        let mut reqs = vec![A::request_null(); 2];
        // Request 0 can never complete; request 1 will.
        check_rc!(
            A::irecv(slice_ptr_mut(&mut a), 1, dt, 0, 30999, A::comm_world(), &mut reqs[0]),
            "irecv never"
        );
        check_rc!(
            A::irecv(slice_ptr_mut(&mut b), 1, dt, 0, 21, A::comm_world(), &mut reqs[1]),
            "irecv real"
        );
        let mut idx = -1;
        let mut st = A::status_empty();
        check_rc!(A::waitany(&mut reqs, &mut idx, &mut st), "waitany");
        check!(idx == 1, "completed index is 1, got {idx}");
        check!(b[0] == 5, "payload");
        // Clean up the never-matching request.
        check_rc!(A::cancel(&mut reqs[0]), "cancel leftover");
        let mut st2 = A::status_empty();
        check_rc!(A::wait(&mut reqs[0], &mut st2), "wait leftover");
    }
    Ok(())
}

/// `MPI_Testany` over a mixed list: flag=false while nothing is ready,
/// the completed index once the message lands, and `MPI_UNDEFINED` with
/// flag=true when the list holds only null handles.
fn testany_polls<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    if me == 0 {
        let mut buf = [0i32; 2];
        let mut reqs = vec![A::request_null(); 2];
        check_rc!(
            A::irecv(slice_ptr_mut(&mut buf), 2, dt, 1, 5, A::comm_world(), &mut reqs[1]),
            "irecv"
        );
        let (mut index, mut flag) = (0i32, false);
        let mut st = A::status_empty();
        loop {
            check_rc!(A::testany(&mut reqs, &mut index, &mut flag, &mut st), "testany");
            if flag {
                break;
            }
        }
        check!(index == 1, "completed index: {index}");
        check!(reqs[1] == A::request_null(), "handle nulled");
        check!(buf == [7, 8], "payload {buf:?}");
        // Only nulls left: flag=true with MPI_UNDEFINED.
        check_rc!(A::testany(&mut reqs, &mut index, &mut flag, &mut st), "testany nulls");
        check!(flag && index == A::undefined(), "all-null testany: flag={flag} idx={index}");
    } else if me == 1 {
        let v = [7i32, 8];
        check_rc!(A::send(slice_ptr(&v), 2, dt, 0, 5, A::comm_world()), "send");
    }
    check_rc!(A::barrier(A::comm_world()), "exit barrier");
    Ok(())
}

/// `MPI_Waitsome` returns a batch of completed receives; repeated calls
/// drain the list, and an all-null list reports `MPI_UNDEFINED`.
fn waitsome_batch<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    const K: usize = 3;
    if me == 0 {
        let mut bufs = vec![[0i32; 1]; K];
        let mut reqs = vec![A::request_null(); K];
        for (i, b) in bufs.iter_mut().enumerate() {
            check_rc!(
                A::irecv(slice_ptr_mut(b), 1, dt, 1, i as i32 + 20, A::comm_world(),
                    &mut reqs[i]),
                "irecv"
            );
        }
        let mut seen = vec![false; K];
        let mut total = 0usize;
        while total < K {
            let mut outcount = 0i32;
            let mut indices = vec![0i32; K];
            let mut sts = vec![A::status_empty(); K];
            check_rc!(A::waitsome(&mut reqs, &mut outcount, &mut indices, &mut sts),
                "waitsome");
            check!(outcount >= 1, "waitsome returns at least one, got {outcount}");
            for j in 0..outcount as usize {
                let i = indices[j] as usize;
                check!(!seen[i], "index {i} reported twice");
                seen[i] = true;
                check!(A::status_tag(&sts[j]) == i as i32 + 20, "status tag for {i}");
                check!(reqs[i] == A::request_null(), "handle {i} nulled");
            }
            total += outcount as usize;
        }
        for (i, b) in bufs.iter().enumerate() {
            check!(b[0] == i as i32 * 11, "payload {i}: {}", b[0]);
        }
        // Exhausted list: outcount = MPI_UNDEFINED.
        let mut outcount = 0i32;
        let mut indices = vec![0i32; K];
        let mut sts = vec![A::status_empty(); K];
        check_rc!(A::waitsome(&mut reqs, &mut outcount, &mut indices, &mut sts),
            "waitsome empty");
        check!(outcount == A::undefined(), "all-null waitsome: {outcount}");
    } else if me == 1 {
        for i in 0..K {
            let v = [i as i32 * 11];
            check_rc!(A::send(slice_ptr(&v), 1, dt, 0, i as i32 + 20, A::comm_world()),
                "send");
        }
    }
    check_rc!(A::barrier(A::comm_world()), "exit barrier");
    Ok(())
}

/// `MPI_Testsome` never blocks: zero completions is a valid outcome, and
/// once the sends land, polling drains every request.
fn testsome_drains<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    const K: usize = 2;
    if me == 0 {
        let mut bufs = vec![[0i32; 1]; K];
        let mut reqs = vec![A::request_null(); K];
        for (i, b) in bufs.iter_mut().enumerate() {
            check_rc!(
                A::irecv(slice_ptr_mut(b), 1, dt, 1, i as i32 + 40, A::comm_world(),
                    &mut reqs[i]),
                "irecv"
            );
        }
        let mut total = 0usize;
        while total < K {
            let mut outcount = 0i32;
            let mut indices = vec![0i32; K];
            let mut sts = vec![A::status_empty(); K];
            check_rc!(A::testsome(&mut reqs, &mut outcount, &mut indices, &mut sts),
                "testsome");
            check!(outcount >= 0, "testsome outcount never negative while active");
            total += outcount as usize;
        }
        check!(bufs[0][0] == 100 && bufs[1][0] == 101, "payloads {bufs:?}");
        let mut outcount = 0i32;
        let mut indices = vec![0i32; K];
        let mut sts = vec![A::status_empty(); K];
        check_rc!(A::testsome(&mut reqs, &mut outcount, &mut indices, &mut sts),
            "testsome empty");
        check!(outcount == A::undefined(), "all-null testsome: {outcount}");
    } else if me == 1 {
        for i in 0..K {
            let v = [100 + i as i32];
            check_rc!(A::send(slice_ptr(&v), 1, dt, 0, i as i32 + 40, A::comm_world()),
                "send");
        }
    }
    check_rc!(A::barrier(A::comm_world()), "exit barrier");
    Ok(())
}
