"""L2 model: shapes, determinism, and that DDP-style training converges."""

import jax.numpy as jnp
import numpy as np

from compile import model


def test_shapes():
    out = model.grad_step(*model.example_args_grad_step())
    assert out[0].shape == ()
    assert out[1].shape == (model.D_IN, model.D_HID)
    assert out[2].shape == (model.D_HID,)
    assert out[3].shape == (model.D_HID, model.D_OUT)
    assert out[4].shape == (model.D_OUT,)


def test_grad_step_deterministic():
    a = model.grad_step(*model.example_args_grad_step())
    b = model.grad_step(*model.example_args_grad_step())
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_sgd_update_moves_against_gradient():
    w1, b1, w2, b2 = model.init_params()
    g = (jnp.ones_like(w1), jnp.ones_like(b1), jnp.ones_like(w2), jnp.ones_like(b2))
    nw1, nb1, nw2, nb2 = model.sgd_update(w1, b1, w2, b2, *g, jnp.float32(0.1))
    np.testing.assert_allclose(nw1, w1 - 0.1, rtol=1e-6)
    np.testing.assert_allclose(nb2, b2 - 0.1, rtol=1e-6)


def test_training_reduces_loss():
    params = model.init_params(0)
    lr = jnp.float32(0.05)
    first = None
    last = None
    for step in range(15):
        x, y = model.synthetic_batch(step)
        loss, *grads = model.grad_step(*params, x, y)
        if first is None:
            first = float(loss)
        last = float(loss)
        params = model.sgd_update(*params, *grads, lr)
    assert last < first * 0.8, f"loss did not decrease: {first} -> {last}"
