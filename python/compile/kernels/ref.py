"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
contract (pytest asserts allclose kernel-vs-ref before artifacts ship)."""

import jax.numpy as jnp


def reduce_ref(a, b, *, op: str):
    if op == "sum":
        return a + b
    if op == "prod":
        return a * b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    raise ValueError(f"unknown op {op}")


def matmul_ref(x, w):
    return jnp.matmul(x, w)


def dense_ref(x, w, b):
    return jnp.matmul(x, w) + b[None, :]
