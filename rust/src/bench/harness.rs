//! The reproducible perf harness behind `cargo run --release --bin
//! abibench`: every (bench, ABI config, transport) cell of the paper's
//! evaluation grid in one run, written to a machine-readable
//! `BENCH_PR5.json` at the repo root so future PRs regress against real
//! numbers instead of prose.
//!
//! Three benches:
//!
//! * `latency_8b` — `osu_latency` analogue, 8-byte one-way ns (E3);
//! * `msgrate_8b` — `osu_mbw_mr` analogue, ns per message at window 64
//!   (E2 / Table 1);
//! * `translation_type_size` — the §6.1 `MPI_Type_size` representation-
//!   decoding cost, per call (E1/E6's smallest translation unit).
//!
//! The two pt2pt benches are additionally run with the **flat-baseline
//! matcher** (`MPI_ABI_FLAT_MATCH=1` semantics, forced per job via
//! [`JobSpec::with_flat_match`]) so the indexed matching engine's win is
//! part of the artifact: `speedup_vs_flat` in the JSON is
//! baseline-ns / indexed-ns (> 1 means the index is faster).
//!
//! Two modes: `--smoke` (seconds; the CI `bench-smoke` job) and
//! `--full` (minutes; the numbers quoted in PR descriptions).

use crate::api::MpiAbi;
use crate::apps::osu::{latency, mbw_mr, type_size_ns, LatencyParams, MbwMrParams};
use crate::apps::{with_abi, AbiApp, AbiConfig};
use crate::core::transport::TransportKind;
use crate::launcher::{run_job_ok, JobSpec};

/// The benches the harness runs, in grid order.
pub const BENCHES: [&str; 3] = ["latency_8b", "msgrate_8b", "translation_type_size"];

/// The two transports of every grid.
pub const TRANSPORTS: [TransportKind; 2] = [TransportKind::Spsc, TransportKind::Mutex];

/// One measured cell of the grid.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Bench name (one of [`BENCHES`]).
    pub bench: &'static str,
    /// ABI configuration name ([`AbiConfig::name`]).
    pub config: &'static str,
    /// Transport name ([`TransportKind::name`]).
    pub transport: &'static str,
    /// Nanoseconds per event (one-way message, one message, one call).
    pub ns: f64,
}

/// Harness options (parsed by the `abibench` binary).
#[derive(Clone, Copy, Debug)]
pub struct HarnessOpts {
    /// Smoke mode: iteration counts small enough for CI.
    pub smoke: bool,
}

/// Iteration counts for one mode.
struct Sizing {
    lat_iters: usize,
    lat_warmup: usize,
    mbw_iters: usize,
    mbw_warmup: usize,
    ts_iters: usize,
    reps: usize,
}

impl Sizing {
    fn of(opts: HarnessOpts) -> Sizing {
        if opts.smoke {
            Sizing {
                lat_iters: 200,
                lat_warmup: 20,
                mbw_iters: 60,
                mbw_warmup: 10,
                ts_iters: 20_000,
                reps: 1,
            }
        } else {
            Sizing {
                lat_iters: 1000,
                lat_warmup: 100,
                mbw_iters: 1000,
                mbw_warmup: 100,
                ts_iters: 200_000,
                reps: 3,
            }
        }
    }
}

struct LatencyRun {
    transport: TransportKind,
    flat: bool,
    iters: usize,
    warmup: usize,
    reps: usize,
}

impl AbiApp<f64> for LatencyRun {
    fn run<A: MpiAbi>(self) -> f64 {
        let mut best = f64::MAX;
        for _ in 0..self.reps {
            let spec = JobSpec::new(2)
                .with_transport(self.transport)
                .with_flat_match(self.flat);
            let out = run_job_ok(spec, |_| {
                A::init();
                let r = latency::<A>(LatencyParams {
                    msg_size: 8,
                    iters: self.iters,
                    warmup: self.warmup,
                });
                A::finalize();
                r
            });
            best = best.min(out[0]);
        }
        best * 1e9
    }
}

struct MsgRateRun {
    transport: TransportKind,
    flat: bool,
    iters: usize,
    warmup: usize,
    reps: usize,
}

impl AbiApp<f64> for MsgRateRun {
    fn run<A: MpiAbi>(self) -> f64 {
        let mut best_rate = 0.0f64;
        for _ in 0..self.reps {
            let spec = JobSpec::new(2)
                .with_transport(self.transport)
                .with_flat_match(self.flat);
            let out = run_job_ok(spec, |_| {
                A::init();
                let r = mbw_mr::<A>(MbwMrParams {
                    msg_size: 8,
                    window: 64,
                    iters: self.iters,
                    warmup: self.warmup,
                });
                A::finalize();
                r
            });
            best_rate = best_rate.max(out[0]);
        }
        1e9 / best_rate // ns per message
    }
}

struct TypeSizeRun {
    iters: usize,
}

impl AbiApp<f64> for TypeSizeRun {
    fn run<A: MpiAbi>(self) -> f64 {
        type_size_ns::<A>(self.iters)
    }
}

fn measure(
    bench: &'static str,
    config: AbiConfig,
    transport: TransportKind,
    flat: bool,
    s: &Sizing,
) -> f64 {
    match bench {
        "latency_8b" => with_abi(
            config,
            LatencyRun {
                transport,
                flat,
                iters: s.lat_iters,
                warmup: s.lat_warmup,
                reps: s.reps,
            },
        ),
        "msgrate_8b" => with_abi(
            config,
            MsgRateRun {
                transport,
                flat,
                iters: s.mbw_iters,
                warmup: s.mbw_warmup,
                reps: s.reps,
            },
        ),
        "translation_type_size" => with_abi(config, TypeSizeRun { iters: s.ts_iters }),
        _ => unreachable!("unknown bench {bench}"),
    }
}

/// The full harness result: every indexed cell, the flat-baseline cells
/// of the two pt2pt benches, and the headline speedups.
pub struct HarnessResult {
    /// Mode the grid was run in (`"smoke"` / `"full"`).
    pub mode: &'static str,
    /// Indexed-matcher cells: every (bench, config, transport).
    pub cells: Vec<Cell>,
    /// Flat-baseline cells (`latency_8b` / `msgrate_8b` only).
    pub flat_baseline: Vec<Cell>,
}

impl HarnessResult {
    /// baseline-ns / indexed-ns for a (bench, config, transport) — the
    /// indexed matcher's speedup (> 1 = faster than flat).
    pub fn speedup(&self, bench: &str, config: &str, transport: &str) -> Option<f64> {
        let pick = |cells: &[Cell]| {
            cells
                .iter()
                .find(|c| c.bench == bench && c.config == config && c.transport == transport)
                .map(|c| c.ns)
        };
        Some(pick(&self.flat_baseline)? / pick(&self.cells)?)
    }
}

/// Run the whole grid. Progress goes to stderr (one line per cell), so
/// redirecting stdout still yields a clean report.
pub fn run_harness(opts: HarnessOpts) -> HarnessResult {
    // Keep XLA client init out of message timings (as the benches do).
    std::env::set_var("MPI_ABI_NO_XLA", "1");
    let s = Sizing::of(opts);
    let mut cells = Vec::new();
    let mut flat_baseline = Vec::new();
    for bench in BENCHES {
        for config in AbiConfig::ALL {
            if bench == "translation_type_size" {
                // Transport-independent (no job runs): measure once per
                // config and publish the same value to both transport
                // cells so the grid stays rectangular without passing
                // re-measurement noise off as a transport effect.
                let ns = measure(bench, config, TRANSPORTS[0], false, &s);
                eprintln!("  [abibench] {bench:<22} {:<11} both  {ns:>12.1} ns", config.name());
                for transport in TRANSPORTS {
                    cells.push(Cell {
                        bench,
                        config: config.name(),
                        transport: transport.name(),
                        ns,
                    });
                }
                continue;
            }
            for transport in TRANSPORTS {
                let ns = measure(bench, config, transport, false, &s);
                eprintln!(
                    "  [abibench] {bench:<22} {:<11} {:<5} {:>12.1} ns",
                    config.name(),
                    transport.name(),
                    ns
                );
                cells.push(Cell {
                    bench,
                    config: config.name(),
                    transport: transport.name(),
                    ns,
                });
                let ns = measure(bench, config, transport, true, &s);
                eprintln!(
                    "  [abibench] {bench:<22} {:<11} {:<5} {:>12.1} ns  (flat baseline)",
                    config.name(),
                    transport.name(),
                    ns
                );
                flat_baseline.push(Cell {
                    bench,
                    config: config.name(),
                    transport: transport.name(),
                    ns,
                });
            }
        }
    }
    HarnessResult {
        mode: if opts.smoke { "smoke" } else { "full" },
        cells,
        flat_baseline,
    }
}

fn json_cell(c: &Cell) -> String {
    format!(
        "    {{\"bench\": \"{}\", \"config\": \"{}\", \"transport\": \"{}\", \"ns\": {:.2}}}",
        c.bench, c.config, c.transport, c.ns
    )
}

/// Render the result as the `BENCH_PR5.json` document (hand-rolled:
/// serde is not in the offline crate set).
pub fn to_json(r: &HarnessResult) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": 1,\n");
    out.push_str("  \"pr\": 5,\n");
    out.push_str("  \"generated_by\": \"abibench\",\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", r.mode));
    out.push_str(&format!(
        "  \"benches\": [{}],\n",
        BENCHES.map(|b| format!("\"{b}\"")).join(", ")
    ));
    out.push_str(&format!(
        "  \"configs\": [{}],\n",
        AbiConfig::ALL.map(|c| format!("\"{}\"", c.name())).join(", ")
    ));
    out.push_str(&format!(
        "  \"transports\": [{}],\n",
        TRANSPORTS.map(|t| format!("\"{}\"", t.name())).join(", ")
    ));
    out.push_str("  \"cells\": [\n");
    let lines: Vec<String> = r.cells.iter().map(json_cell).collect();
    out.push_str(&lines.join(",\n"));
    out.push_str("\n  ],\n");
    out.push_str("  \"flat_baseline\": [\n");
    let lines: Vec<String> = r.flat_baseline.iter().map(json_cell).collect();
    out.push_str(&lines.join(",\n"));
    out.push_str("\n  ],\n");
    out.push_str("  \"speedup_vs_flat\": {\n");
    let mut sp = Vec::new();
    for bench in ["latency_8b", "msgrate_8b"] {
        for transport in TRANSPORTS {
            // Headline: the native standard-ABI build (the paper's
            // "MPICH dev UCX ABI" row).
            if let Some(s) = r.speedup(bench, "abi", transport.name()) {
                sp.push(format!(
                    "    \"{}_{}\": {:.3}",
                    bench,
                    transport.name(),
                    s
                ));
            }
        }
    }
    out.push_str(&sp.join(",\n"));
    out.push_str("\n  }\n");
    out.push_str("}\n");
    out
}

/// Validate a previously written `BENCH_PR5.json`: every (bench,
/// config, transport) cell present **in the `cells` array** with a
/// numeric value, and every (pt2pt bench, config, transport) cell in
/// the `flat_baseline` array. Each grid is checked inside its own array
/// section so a cell present only in the *other* section cannot mask a
/// hole. Returns the list of missing cells (empty = complete). The CI
/// `bench-smoke` job runs this via `abibench --check` after
/// regenerating the file.
pub fn check_json(doc: &str) -> Vec<String> {
    let mut missing = Vec::new();
    let sections = (doc.find("\"cells\": ["), doc.find("\"flat_baseline\": ["));
    let (cells_sec, flat_sec) = match sections {
        (Some(c), Some(f)) if c < f => (&doc[c..f], &doc[f..]),
        _ => {
            missing.push("\"cells\" and \"flat_baseline\" arrays, in that order".to_string());
            return missing;
        }
    };
    check_grid(cells_sec, &BENCHES, "cells", &mut missing);
    check_grid(flat_sec, &["latency_8b", "msgrate_8b"], "flat_baseline", &mut missing);
    missing
}

/// Check one array section for every (bench, config, transport) cell.
fn check_grid(section: &str, benches: &[&str], label: &str, missing: &mut Vec<String>) {
    for &bench in benches {
        for config in AbiConfig::ALL {
            for transport in TRANSPORTS {
                let needle = format!(
                    "\"bench\": \"{}\", \"config\": \"{}\", \"transport\": \"{}\", \"ns\": ",
                    bench,
                    config.name(),
                    transport.name()
                );
                match section.find(&needle) {
                    Some(pos) => {
                        let rest = &section[pos + needle.len()..];
                        let num: String = rest
                            .chars()
                            .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-')
                            .collect();
                        if num.parse::<f64>().map(|v| v.is_finite()).unwrap_or(false) {
                            continue;
                        }
                        missing.push(format!("{label}: {needle}<non-numeric>"));
                    }
                    None => missing.push(format!("{label}: {needle}")),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_result() -> HarnessResult {
        let mut cells = Vec::new();
        let mut flat = Vec::new();
        for bench in BENCHES {
            for config in AbiConfig::ALL {
                for transport in TRANSPORTS {
                    cells.push(Cell {
                        bench,
                        config: config.name(),
                        transport: transport.name(),
                        ns: 100.0,
                    });
                    if bench != "translation_type_size" {
                        flat.push(Cell {
                            bench,
                            config: config.name(),
                            transport: transport.name(),
                            ns: 150.0,
                        });
                    }
                }
            }
        }
        HarnessResult { mode: "smoke", cells, flat_baseline: flat }
    }

    #[test]
    fn json_roundtrips_the_completeness_check() {
        let doc = to_json(&fake_result());
        assert!(check_json(&doc).is_empty(), "generated JSON must be complete");
    }

    #[test]
    fn check_flags_missing_cells() {
        let doc = to_json(&fake_result());
        // Break only the first occurrence — the `cells` array entry; its
        // flat_baseline twin must NOT mask the hole.
        let broken = doc.replacen(
            "\"bench\": \"latency_8b\", \"config\": \"mpich\", \"transport\": \"spsc\"",
            "\"bench\": \"gone\", \"config\": \"mpich\", \"transport\": \"spsc\"",
            1,
        );
        let missing = check_json(&broken);
        assert_eq!(missing.len(), 1, "{missing:?}");
        assert!(missing[0].starts_with("cells: "), "{missing:?}");
    }

    #[test]
    fn check_validates_flat_baseline_section_too() {
        let doc = to_json(&fake_result());
        // Remove the flat_baseline array entirely: structural failure.
        let broken = doc.replace("\"flat_baseline\": [", "\"flat_gone\": [");
        assert!(!check_json(&broken).is_empty());
        // Break one flat cell (second occurrence of the needle).
        let pos = doc.rfind("\"bench\": \"msgrate_8b\", \"config\": \"abi\"").unwrap();
        let broken = format!("{}{}", &doc[..pos], doc[pos..].replacen("msgrate_8b", "gone", 1));
        let missing = check_json(&broken);
        assert_eq!(missing.len(), 1, "{missing:?}");
        assert!(missing[0].starts_with("flat_baseline: "), "{missing:?}");
    }

    #[test]
    fn speedup_is_baseline_over_indexed() {
        let r = fake_result();
        let s = r.speedup("latency_8b", "abi", "spsc").unwrap();
        assert!((s - 1.5).abs() < 1e-9);
    }

    #[test]
    fn smoke_grid_sizing_is_small() {
        let s = Sizing::of(HarnessOpts { smoke: true });
        assert!(s.lat_iters <= 1000 && s.reps == 1);
    }
}
