//! The ULFM fault-tolerance battery, standalone: all five ABI
//! configurations × both transports. Every scenario injects (or
//! simulates) a failure and asserts the ULFM contract — blocked
//! operations *fail* with `MPI_ERR_PROC_FAILED` /
//! `MPI_ERR_PROC_FAILED_PENDING` / `MPI_ERR_REVOKED` instead of
//! hanging, and revoke/shrink/agree recover a working communicator.
//!
//! The `abirun halo --kill` acceptance (survivor residuals bitwise
//! identical across configs after shrink + re-decomposition) lives in
//! `tests/property_tests.rs`, which reuses the same fault-tolerant
//! stencil as its oracle.

use mpi_abi::api::MpiAbi;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;
use mpi_abi::testsuite;

fn battery<A: MpiAbi>() {
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        for (name, scenario) in testsuite::ulfm_scenarios::<A>() {
            if let Err(m) = scenario(transport) {
                panic!("[{} {:?}] {name}: {m}", A::NAME, transport);
            }
        }
    }
}

#[test]
fn ulfm_battery_mpich_native() {
    battery::<MpichAbi>();
}

#[test]
fn ulfm_battery_ompi_native() {
    battery::<OmpiAbi>();
}

#[test]
fn ulfm_battery_muk_over_mpich() {
    battery::<MukMpich>();
}

#[test]
fn ulfm_battery_muk_over_ompi() {
    battery::<MukOmpi>();
}

#[test]
fn ulfm_battery_native_standard_abi() {
    battery::<NativeAbi>();
}

/// The indexed matcher is the default; the ULFM checks sit on its miss
/// paths *and* on the flat baseline's request paths — prove the flat
/// matcher honors the same failure contract.
#[test]
fn ulfm_battery_flat_baseline() {
    use mpi_abi::abi::errors as ec;
    use mpi_abi::launcher::{run_job, JobSpec, RankOutcome};
    type A = NativeAbi;
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        let spec = JobSpec::new(2).with_transport(transport).with_kill(1, 3).with_flat_match(true);
        let out = run_job(spec, |rank| {
            assert_eq!(A::init(), 0);
            let dt = A::datatype(mpi_abi::api::Dt::Int);
            let world = A::comm_world();
            let mut st = A::status_empty();
            let mut v = 0i32;
            if rank == 1 {
                let _ = A::recv(&mut v as *mut i32 as *mut u8, 1, dt, 0, 31999, world, &mut st);
                return;
            }
            A::comm_set_errhandler(world, A::errhandler_return());
            let rc = A::recv(&mut v as *mut i32 as *mut u8, 1, dt, 1, 7, world, &mut st);
            assert_ne!(rc, 0, "flat-match recv from dead peer returned success");
            assert_eq!(A::err_class_of(rc), ec::MPI_ERR_PROC_FAILED, "{transport:?}");
        });
        assert!(matches!(out[0], RankOutcome::Ok(())), "{transport:?}");
        assert!(matches!(out[1], RankOutcome::Killed), "{transport:?}");
    }
}
