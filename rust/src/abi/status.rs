//! The standard-ABI status object (§5.2).
//!
//! ```c
//! typedef struct MPI_Status {
//!     int MPI_SOURCE;
//!     int MPI_TAG;
//!     int MPI_ERROR;
//!     int mpi_reserved[5];
//! } MPI_Status;
//! ```
//!
//! 32 bytes total: good alignment for arrays of statuses, and at least two
//! more hidden slots than any of the surveyed implementations (new-MPICH
//! needs 2, Open MPI needs 3 incl. a `size_t`), leaving slack for future
//! needs — including the §4.8 use case of tools hiding state in the
//! reserved fields.
//!
//! The *layout* of the reserved fields is implementation-private. We define
//! the convention our native implementation of the standard ABI uses (and
//! that Mukautuva's converter produces), mirroring new-MPICH:
//! `reserved[0] = count_lo`, `reserved[1] = count_hi_and_cancelled`
//! (bit 31 = cancelled flag, bits 0..31 = count high bits).

/// The standard ABI `MPI_Status`. `#[repr(C)]`, exactly 32 bytes.
#[repr(C)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[allow(non_snake_case)]
pub struct AbiStatus {
    /// Rank of the message's sender (`MPI_SOURCE` public field).
    pub MPI_SOURCE: i32,
    /// Tag the message was sent with (`MPI_TAG` public field).
    pub MPI_TAG: i32,
    /// Error class for this operation (`MPI_ERROR` public field).
    pub MPI_ERROR: i32,
    /// Implementation-private slots; see the module docs for the layout
    /// convention this build uses (count + cancelled flag, tool slack).
    pub mpi_reserved: [i32; 5],
}

const _: () = assert!(core::mem::size_of::<AbiStatus>() == 32);
const _: () = assert!(core::mem::align_of::<AbiStatus>() == 4);

impl AbiStatus {
    /// An empty status: like `MPI_STATUS_IGNORE`-adjacent zero state.
    pub const fn empty() -> AbiStatus {
        AbiStatus { MPI_SOURCE: 0, MPI_TAG: 0, MPI_ERROR: 0, mpi_reserved: [0; 5] }
    }

    /// Pack the hidden byte count (63-bit) + cancelled flag into the
    /// reserved fields, new-MPICH style.
    pub fn set_count_and_cancelled(&mut self, count_bytes: u64, cancelled: bool) {
        debug_assert!(count_bytes < (1u64 << 63), "count must fit 63 bits");
        self.mpi_reserved[0] = (count_bytes & 0xFFFF_FFFF) as u32 as i32;
        let hi = ((count_bytes >> 32) & 0x7FFF_FFFF) as u32;
        let hi = hi | if cancelled { 0x8000_0000 } else { 0 };
        self.mpi_reserved[1] = hi as i32;
    }

    /// Hidden byte count stored by [`Self::set_count_and_cancelled`].
    pub fn count_bytes(&self) -> u64 {
        let lo = self.mpi_reserved[0] as u32 as u64;
        let hi = (self.mpi_reserved[1] as u32 & 0x7FFF_FFFF) as u64;
        (hi << 32) | lo
    }

    /// Hidden cancelled flag.
    pub fn cancelled(&self) -> bool {
        (self.mpi_reserved[1] as u32) & 0x8000_0000 != 0
    }

    /// Reserved slots 2..5 are free for tools (§4.8). Returns a mutable
    /// view so a PMPI/QMPI-style tool can stash state.
    pub fn tool_slots(&mut self) -> &mut [i32] {
        &mut self.mpi_reserved[2..]
    }
}

impl Default for AbiStatus {
    fn default() -> Self {
        Self::empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_32_bytes() {
        assert_eq!(core::mem::size_of::<AbiStatus>(), 32);
        assert_eq!(core::mem::align_of::<AbiStatus>(), 4);
    }

    #[test]
    fn public_fields_lead() {
        // The three public members must be at the front, in order, so that
        // `status.MPI_SOURCE` etc. work across implementations.
        let s = AbiStatus { MPI_SOURCE: 1, MPI_TAG: 2, MPI_ERROR: 3, mpi_reserved: [0; 5] };
        let base = &s as *const _ as usize;
        assert_eq!(&s.MPI_SOURCE as *const _ as usize - base, 0);
        assert_eq!(&s.MPI_TAG as *const _ as usize - base, 4);
        assert_eq!(&s.MPI_ERROR as *const _ as usize - base, 8);
    }

    #[test]
    fn count_roundtrip() {
        let mut s = AbiStatus::empty();
        for &c in &[0u64, 1, 8, 0xFFFF_FFFF, 0x1_0000_0000, (1u64 << 62) + 12345] {
            for &x in &[false, true] {
                s.set_count_and_cancelled(c, x);
                assert_eq!(s.count_bytes(), c);
                assert_eq!(s.cancelled(), x);
            }
        }
    }

    #[test]
    fn cancelled_does_not_clobber_count() {
        let mut s = AbiStatus::empty();
        s.set_count_and_cancelled(u64::MAX >> 1, true);
        assert_eq!(s.count_bytes(), u64::MAX >> 1);
        assert!(s.cancelled());
    }

    #[test]
    fn tool_slots_are_three() {
        let mut s = AbiStatus::empty();
        assert_eq!(s.tool_slots().len(), 3);
        s.tool_slots()[0] = 42;
        assert_eq!(s.mpi_reserved[2], 42);
        // Tool slots must not alias the count/cancelled fields.
        s.set_count_and_cancelled(7, true);
        assert_eq!(s.mpi_reserved[2], 42);
    }

    #[test]
    fn array_of_statuses_is_dense() {
        // §5.2 motivates 32 bytes by array alignment.
        let arr = [AbiStatus::empty(); 4];
        assert_eq!(core::mem::size_of_val(&arr), 128);
    }
}
