//! The end-to-end driver (E8): data-parallel training of the L2 model.
//!
//! Every rank runs the AOT-compiled `grad_step` executable (L2 JAX graph
//! containing the L1 Pallas matmul kernel) on its own synthetic shard,
//! averages gradients across ranks with `MPI_Allreduce` through the
//! chosen ABI (L3 — which itself offloads large f32 sums to the compiled
//! Pallas *reduce* kernel), then applies the compiled `sgd_update`.
//! All three layers compose on every step.

use crate::api::{Dt, MpiAbi, OpName};
use crate::runtime::runtime;

/// Model geometry — must match `python/compile/model.py`.
pub const D_IN: usize = 256;
pub const D_HID: usize = 256;
pub const D_OUT: usize = 128;
pub const BATCH: usize = 128;

pub struct DdpParams {
    pub steps: usize,
    pub lr: f32,
    /// Log the loss every `log_every` steps (0 = never).
    pub log_every: usize,
}

impl Default for DdpParams {
    fn default() -> Self {
        DdpParams { steps: 40, lr: 0.05, log_every: 5 }
    }
}

pub struct DdpResult {
    /// (step, mean loss across ranks).
    pub loss_curve: Vec<(usize, f32)>,
    pub final_loss: f32,
}

/// Deterministic pseudo-random init/data (xorshift; no rand crate).
fn fill_randn(buf: &mut [f32], seed: u64, scale: f32) {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
    let mut next = || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s
    };
    for x in buf.iter_mut() {
        // Sum of 4 uniforms ≈ gaussian-ish, centered.
        let mut acc = 0.0f32;
        for _ in 0..4 {
            acc += (next() >> 40) as f32 / (1u64 << 24) as f32;
        }
        *x = (acc - 2.0) * scale;
    }
}

/// Run DDP training; call from every rank after `A::init()`.
/// Panics if artifacts are unavailable (run `make artifacts`).
pub fn train<A: MpiAbi>(p: DdpParams) -> DdpResult {
    let rt = runtime().expect("DDP needs AOT artifacts: run `make artifacts`");
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    let world = A::comm_world();
    let dt_f = A::datatype(Dt::Float);
    let op_sum = A::op(OpName::Sum);

    // Identical init on every rank (same seed), per-rank data shards.
    let mut w1 = vec![0f32; D_IN * D_HID];
    let mut b1 = vec![0f32; D_HID];
    let mut w2 = vec![0f32; D_HID * D_OUT];
    let mut b2 = vec![0f32; D_OUT];
    fill_randn(&mut w1, 1, 1.0 / (D_IN as f32).sqrt());
    fill_randn(&mut w2, 2, 1.0 / (D_HID as f32).sqrt());

    // Fixed teacher for the synthetic regression target.
    let mut teacher = vec![0f32; D_IN];
    fill_randn(&mut teacher, 7, 1.0);

    let mut loss_curve = Vec::new();
    let mut final_loss = f32::NAN;
    let inv_n = 1.0 / n as f32;

    for step in 0..p.steps {
        // Per-rank shard: new batch every step, disjoint across ranks.
        let mut x = vec![0f32; BATCH * D_IN];
        fill_randn(&mut x, (step as u64) << 8 | (me as u64 + 1), 1.0);
        let mut y = vec![0f32; BATCH];
        for (i, yy) in y.iter_mut().enumerate() {
            let row = &x[i * D_IN..(i + 1) * D_IN];
            let dot: f32 = row.iter().zip(&teacher).map(|(a, b)| a * b).sum();
            *yy = dot.tanh();
        }

        // L2+L1: compiled forward/backward.
        let outs = rt
            .execute_f32(
                "grad_step",
                &[
                    (&w1, &[D_IN as i64, D_HID as i64]),
                    (&b1, &[D_HID as i64]),
                    (&w2, &[D_HID as i64, D_OUT as i64]),
                    (&b2, &[D_OUT as i64]),
                    (&x, &[BATCH as i64, D_IN as i64]),
                    (&y, &[BATCH as i64]),
                ],
            )
            .expect("grad_step");
        let local_loss = outs[0][0];
        let mut grads = [
            outs[1].clone(),
            outs[2].clone(),
            outs[3].clone(),
            outs[4].clone(),
        ];

        // L3: average gradients across ranks (w1 grad is 65536 elements —
        // exactly the XLA-offloaded allreduce size).
        let mut mean_loss = local_loss;
        for g in grads.iter_mut() {
            let rc = A::allreduce(
                A::in_place(),
                g.as_mut_ptr() as *mut u8,
                g.len() as i32,
                dt_f,
                op_sum,
                world,
            );
            assert_eq!(rc, 0, "allreduce failed");
            for v in g.iter_mut() {
                *v *= inv_n;
            }
        }
        {
            let rc = A::allreduce(
                A::in_place(),
                &mut mean_loss as *mut f32 as *mut u8,
                1,
                dt_f,
                op_sum,
                world,
            );
            assert_eq!(rc, 0);
            mean_loss *= inv_n;
        }

        // L2: compiled optimizer step.
        let lr = [p.lr];
        let upd = rt
            .execute_f32(
                "sgd_update",
                &[
                    (&w1, &[D_IN as i64, D_HID as i64]),
                    (&b1, &[D_HID as i64]),
                    (&w2, &[D_HID as i64, D_OUT as i64]),
                    (&b2, &[D_OUT as i64]),
                    (&grads[0], &[D_IN as i64, D_HID as i64]),
                    (&grads[1], &[D_HID as i64]),
                    (&grads[2], &[D_HID as i64, D_OUT as i64]),
                    (&grads[3], &[D_OUT as i64]),
                    (&lr, &[]),
                ],
            )
            .expect("sgd_update");
        w1 = upd[0].clone();
        b1 = upd[1].clone();
        w2 = upd[2].clone();
        b2 = upd[3].clone();

        final_loss = mean_loss;
        if p.log_every > 0 && step % p.log_every == 0 {
            loss_curve.push((step, mean_loss));
            if me == 0 {
                eprintln!("[ddp {}] step {step:4}  loss {mean_loss:.6}", A::NAME);
            }
        }
    }
    loss_curve.push((p.steps, final_loss));
    DdpResult { loss_curve, final_loss }
}
