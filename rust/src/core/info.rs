//! `MPI_Info` objects: ordered string key/value stores.

use super::slab::Slab;
use super::world::with_ctx;
use super::{err, InfoId, RC};
use crate::abi::constants::{MPI_MAX_INFO_KEY, MPI_MAX_INFO_VAL};

/// Info-object table entry.
#[derive(Clone, Debug, Default)]
pub struct InfoObj {
    /// Insertion-ordered (key, value) pairs; keys unique.
    pub entries: Vec<(String, String)>,
    /// Predefined infos (`MPI_INFO_ENV`) are not freeable.
    pub predefined: bool,
}

/// Install `MPI_INFO_ENV` at its reserved id.
pub fn install_predefined(infos: &mut Slab<InfoObj>) {
    // MPI_INFO_ENV: a few environment facts, like real implementations.
    let entries = vec![
        ("command".to_string(), std::env::args().next().unwrap_or_default()),
        ("maxprocs".to_string(), String::new()),
    ];
    infos.insert_at(super::reserved::INFO_ENV.0, InfoObj { entries, predefined: true });
}

/// `MPI_Info_create`.
pub fn info_create() -> RC<InfoId> {
    with_ctx(|ctx| Ok(InfoId(ctx.tables.borrow_mut().infos.insert(InfoObj::default()))))
}

/// `MPI_Info_set`.
pub fn info_set(id: InfoId, key: &str, value: &str) -> RC<()> {
    if key.is_empty() || key.len() > MPI_MAX_INFO_KEY {
        return Err(err!(MPI_ERR_INFO_KEY));
    }
    if value.len() > MPI_MAX_INFO_VAL {
        return Err(err!(MPI_ERR_INFO_VALUE));
    }
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let info = t.infos.get_mut(id.0).ok_or(err!(MPI_ERR_INFO))?;
        if let Some(e) = info.entries.iter_mut().find(|(k, _)| k == key) {
            e.1 = value.to_string();
        } else {
            info.entries.push((key.to_string(), value.to_string()));
        }
        Ok(())
    })
}

/// `MPI_Info_get` (returns `None` if the key is absent — flag=false).
pub fn info_get(id: InfoId, key: &str) -> RC<Option<String>> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let info = t.infos.get(id.0).ok_or(err!(MPI_ERR_INFO))?;
        Ok(info.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v.clone()))
    })
}

/// `MPI_Info_delete`.
pub fn info_delete(id: InfoId, key: &str) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let info = t.infos.get_mut(id.0).ok_or(err!(MPI_ERR_INFO))?;
        let n = info.entries.len();
        info.entries.retain(|(k, _)| k != key);
        if info.entries.len() == n {
            Err(err!(MPI_ERR_INFO_NOKEY))
        } else {
            Ok(())
        }
    })
}

/// `MPI_Info_dup`.
pub fn info_dup(id: InfoId) -> RC<InfoId> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let src = t.infos.get(id.0).ok_or(err!(MPI_ERR_INFO))?;
        let copy = InfoObj { entries: src.entries.clone(), predefined: false };
        Ok(InfoId(t.infos.insert(copy)))
    })
}

/// `MPI_Info_get_nkeys`.
pub fn info_get_nkeys(id: InfoId) -> RC<i32> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        Ok(t.infos.get(id.0).ok_or(err!(MPI_ERR_INFO))?.entries.len() as i32)
    })
}

/// `MPI_Info_get_nthkey`.
pub fn info_get_nthkey(id: InfoId, n: i32) -> RC<String> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let info = t.infos.get(id.0).ok_or(err!(MPI_ERR_INFO))?;
        info.entries.get(n as usize).map(|(k, _)| k.clone()).ok_or(err!(MPI_ERR_ARG))
    })
}

/// `MPI_Info_free`.
pub fn info_free(id: InfoId) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        match t.infos.get(id.0) {
            Some(i) if i.predefined => Err(err!(MPI_ERR_INFO)),
            Some(_) => {
                t.infos.remove(id.0);
                Ok(())
            }
            None => Err(err!(MPI_ERR_INFO)),
        }
    })
}
