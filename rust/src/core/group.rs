//! Process groups (`MPI_Group`).
//!
//! A group is an ordered set of world ranks. Group operations are purely
//! local (no communication), exactly as in MPI.

use super::slab::Slab;
use super::world::with_ctx;
use super::{err, GroupId, RC};
use crate::abi::constants::MPI_UNDEFINED;

/// Group object: member world ranks in group-rank order.
#[derive(Clone, Debug)]
pub struct GroupObj {
    /// Member world ranks, group-rank order.
    pub members: Vec<usize>,
    /// Predefined groups are not freeable.
    pub predefined: bool,
}

impl GroupObj {
    /// Number of members.
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Group rank of `world_rank`, if a member.
    pub fn rank_of(&self, world_rank: usize) -> Option<usize> {
        self.members.iter().position(|&m| m == world_rank)
    }
}

/// Install `MPI_GROUP_EMPTY` (id 0); the world/self groups (ids 1, 2)
/// are sized when the rank binds.
pub fn install_predefined(groups: &mut Slab<GroupObj>) {
    groups.insert_at(
        super::reserved::GROUP_EMPTY.0,
        GroupObj { members: Vec::new(), predefined: true },
    );
    // World/self member lists are filled by comm::install_predefined's
    // caller context... they depend on world size/rank which bind_rank
    // knows; we install placeholders and fix in `finish_predefined`.
    groups.insert_at(
        super::reserved::GROUP_WORLD.0,
        GroupObj { members: Vec::new(), predefined: true },
    );
    groups.insert_at(
        super::reserved::GROUP_SELF.0,
        GroupObj { members: Vec::new(), predefined: true },
    );
}

/// Size the predefined world/self groups once rank and world size are
/// known (called from engine::init).
pub fn finish_predefined(groups: &mut Slab<GroupObj>, world_size: usize, rank: usize) {
    groups.get_mut(super::reserved::GROUP_WORLD.0).unwrap().members = (0..world_size).collect();
    groups.get_mut(super::reserved::GROUP_SELF.0).unwrap().members = vec![rank];
}

fn get(id: GroupId) -> RC<GroupObj> {
    with_ctx(|ctx| {
        ctx.tables.borrow().groups.get(id.0).cloned().ok_or(err!(MPI_ERR_GROUP))
    })
}

/// `MPI_Group_size`.
pub fn group_size(id: GroupId) -> RC<i32> {
    Ok(get(id)?.size() as i32)
}

/// `MPI_Group_rank`: the calling process's rank in the group, or
/// `MPI_UNDEFINED`.
pub fn group_rank(id: GroupId) -> RC<i32> {
    let g = get(id)?;
    with_ctx(|ctx| Ok(g.rank_of(ctx.rank).map(|r| r as i32).unwrap_or(MPI_UNDEFINED)))
}

fn insert(g: GroupObj) -> RC<GroupId> {
    with_ctx(|ctx| Ok(GroupId(ctx.tables.borrow_mut().groups.insert(g))))
}

/// `MPI_Group_incl`.
pub fn group_incl(id: GroupId, ranks: &[i32]) -> RC<GroupId> {
    let g = get(id)?;
    let mut members = Vec::with_capacity(ranks.len());
    for &r in ranks {
        let r = r as usize;
        if r >= g.members.len() {
            return Err(err!(MPI_ERR_RANK));
        }
        members.push(g.members[r]);
    }
    insert(GroupObj { members, predefined: false })
}

/// `MPI_Group_excl`.
pub fn group_excl(id: GroupId, ranks: &[i32]) -> RC<GroupId> {
    let g = get(id)?;
    let excl: std::collections::HashSet<usize> = ranks.iter().map(|&r| r as usize).collect();
    for &r in ranks {
        if (r as usize) >= g.members.len() {
            return Err(err!(MPI_ERR_RANK));
        }
    }
    let members =
        g.members.iter().enumerate().filter(|(i, _)| !excl.contains(i)).map(|(_, &m)| m).collect();
    insert(GroupObj { members, predefined: false })
}

/// `MPI_Group_union`: members of `a` then members of `b` not in `a`.
pub fn group_union(a: GroupId, b: GroupId) -> RC<GroupId> {
    let (ga, gb) = (get(a)?, get(b)?);
    let mut members = ga.members.clone();
    for &m in &gb.members {
        if !members.contains(&m) {
            members.push(m);
        }
    }
    insert(GroupObj { members, predefined: false })
}

/// `MPI_Group_intersection`: members of `a` that are in `b`, in `a` order.
pub fn group_intersection(a: GroupId, b: GroupId) -> RC<GroupId> {
    let (ga, gb) = (get(a)?, get(b)?);
    let members = ga.members.iter().filter(|m| gb.members.contains(m)).copied().collect();
    insert(GroupObj { members, predefined: false })
}

/// `MPI_Group_difference`: members of `a` not in `b`, in `a` order.
pub fn group_difference(a: GroupId, b: GroupId) -> RC<GroupId> {
    let (ga, gb) = (get(a)?, get(b)?);
    let members = ga.members.iter().filter(|m| !gb.members.contains(m)).copied().collect();
    insert(GroupObj { members, predefined: false })
}

/// `MPI_Group_translate_ranks`.
pub fn group_translate_ranks(a: GroupId, ranks: &[i32], b: GroupId) -> RC<Vec<i32>> {
    let (ga, gb) = (get(a)?, get(b)?);
    let mut out = Vec::with_capacity(ranks.len());
    for &r in ranks {
        if r == crate::abi::constants::MPI_PROC_NULL {
            out.push(r);
            continue;
        }
        let r = r as usize;
        if r >= ga.members.len() {
            return Err(err!(MPI_ERR_RANK));
        }
        out.push(gb.rank_of(ga.members[r]).map(|x| x as i32).unwrap_or(MPI_UNDEFINED));
    }
    Ok(out)
}

/// `MPI_Group_compare`.
pub fn group_compare(a: GroupId, b: GroupId) -> RC<i32> {
    use crate::abi::constants::{MPI_IDENT, MPI_SIMILAR, MPI_UNEQUAL};
    let (ga, gb) = (get(a)?, get(b)?);
    if ga.members == gb.members {
        return Ok(MPI_IDENT);
    }
    let sa: std::collections::HashSet<_> = ga.members.iter().collect();
    let sb: std::collections::HashSet<_> = gb.members.iter().collect();
    Ok(if sa == sb { MPI_SIMILAR } else { MPI_UNEQUAL })
}

/// `MPI_Group_free`.
pub fn group_free(id: GroupId) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        match t.groups.get(id.0) {
            Some(g) if g.predefined => Err(err!(MPI_ERR_GROUP)),
            Some(_) => {
                t.groups.remove(id.0);
                Ok(())
            }
            None => Err(err!(MPI_ERR_GROUP)),
        }
    })
}

/// Create a group directly from world ranks (engine-internal, used by
/// comm creation).
pub fn group_from_members(members: Vec<usize>) -> RC<GroupId> {
    insert(GroupObj { members, predefined: false })
}
