//! The MPI_T tools-interface battery, standalone: all five ABI
//! configurations × both transports (the ISSUE-8 acceptance grid),
//! plus trace-machinery checks — a traced job yields events from every
//! rank and a valid Chrome trace document, and a job without tracing
//! yields exactly zero events (the one-branch-off guarantee).

use mpi_abi::api::MpiAbi;
use mpi_abi::core::obs::{chrome_trace_json, TraceKind};
use mpi_abi::core::transport::TransportKind;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::launcher::{run_job_ok, run_job_traced, JobSpec};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;
use mpi_abi::testsuite;

fn run_battery<A: MpiAbi>(ranks: usize, transport: TransportKind, flat: Option<bool>) {
    let mut spec = JobSpec::new(ranks).with_transport(transport);
    if let Some(f) = flat {
        spec = spec.with_flat_match(f);
    }
    let reports = run_job_ok(spec, |rank| {
        assert_eq!(A::init(), 0, "{} init", A::NAME);
        let results = testsuite::run_registry::<A>(rank, testsuite::mpi_t_registry::<A>());
        let report = testsuite::report(A::NAME, &results);
        let failed = results.iter().filter(|r| !r.passed).count();
        assert_eq!(A::finalize(), 0, "{} finalize", A::NAME);
        (report, failed)
    });
    let (report, failures) = &reports[0];
    if *failures > 0 {
        panic!("[{} {:?} flat={flat:?}]\n{report}", A::NAME, transport);
    }
}

fn both_transports<A: MpiAbi>(ranks: usize) {
    run_battery::<A>(ranks, TransportKind::Spsc, None);
    run_battery::<A>(ranks, TransportKind::Mutex, None);
}

#[test]
fn mpi_t_battery_mpich_native() {
    both_transports::<MpichAbi>(3);
}

#[test]
fn mpi_t_battery_ompi_native() {
    both_transports::<OmpiAbi>(3);
}

#[test]
fn mpi_t_battery_muk_over_mpich() {
    both_transports::<MukMpich>(3);
}

#[test]
fn mpi_t_battery_muk_over_ompi() {
    both_transports::<MukOmpi>(3);
}

#[test]
fn mpi_t_battery_native_standard_abi() {
    both_transports::<NativeAbi>(3);
}

/// The flat-baseline matcher must report the identical scripted-exchange
/// counters: the pvar registry observes semantics, not the engine's
/// data-structure choice.
#[test]
fn mpi_t_battery_flat_baseline_identical() {
    run_battery::<NativeAbi>(3, TransportKind::Spsc, Some(true));
    run_battery::<NativeAbi>(3, TransportKind::Mutex, Some(true));
}

/// A scripted pingpong under `with_trace(true)`: every rank contributes
/// events, the expected kinds show up (post/match on both sides, a
/// completion everywhere), and the merged document is loadable Chrome
/// trace JSON.
fn traced_pingpong(transport: TransportKind) {
    use mpi_abi::core::reserved::COMM_WORLD;
    use mpi_abi::core::{datatype, engine};
    let spec = JobSpec::new(2).with_transport(transport).with_trace(true);
    let (outcomes, trace) = run_job_traced(spec, |rank| {
        engine::init().unwrap();
        let dt = datatype::builtin_id_of_abi(mpi_abi::abi::datatypes::MPI_BYTE).unwrap();
        let mut buf = [0u8; 64];
        if rank == 0 {
            engine::send(
                buf.as_ptr(),
                64,
                dt,
                1,
                11,
                COMM_WORLD,
                engine::SendMode::Standard,
            )
            .unwrap();
            engine::recv(buf.as_mut_ptr(), 64, dt, 1, 12, COMM_WORLD).unwrap();
        } else {
            engine::recv(buf.as_mut_ptr(), 64, dt, 0, 11, COMM_WORLD).unwrap();
            engine::send(
                buf.as_ptr(),
                64,
                dt,
                0,
                12,
                COMM_WORLD,
                engine::SendMode::Standard,
            )
            .unwrap();
        }
        engine::finalize().unwrap();
    });
    for o in &outcomes {
        assert!(o.is_ok());
    }
    assert_eq!(trace.len(), 2, "both ranks must contribute trace events");
    for (rank, events) in &trace {
        assert!(!events.is_empty(), "rank {rank} produced no events");
        assert!(
            events.iter().any(|e| matches!(e.kind, TraceKind::Post)),
            "rank {rank} has no post event"
        );
        assert!(
            events.iter().any(|e| matches!(e.kind, TraceKind::Match)),
            "rank {rank} has no match event"
        );
        assert!(
            events.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns),
            "rank {rank} events out of timestamp order"
        );
    }
    let json = chrome_trace_json(&trace);
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"name\": \"post\""));
    assert!(json.contains("\"name\": \"match\""));
}

#[test]
fn traced_pingpong_both_transports() {
    traced_pingpong(TransportKind::Spsc);
    traced_pingpong(TransportKind::Mutex);
}

/// Without `with_trace` (and without `MPI_ABI_TRACE` in the test env)
/// the very same job must record exactly zero events — tracing off
/// means one branch on a cached bool, not a smaller trace.
#[test]
fn trace_disabled_records_nothing() {
    use mpi_abi::core::reserved::COMM_WORLD;
    use mpi_abi::core::{datatype, engine};
    let spec = JobSpec::new(2);
    let (outcomes, trace) = run_job_traced(spec, |rank| {
        engine::init().unwrap();
        let dt = datatype::builtin_id_of_abi(mpi_abi::abi::datatypes::MPI_BYTE).unwrap();
        let mut buf = [0u8; 8];
        if rank == 0 {
            engine::send(
                buf.as_ptr(),
                8,
                dt,
                1,
                5,
                COMM_WORLD,
                engine::SendMode::Standard,
            )
            .unwrap();
        } else {
            engine::recv(buf.as_mut_ptr(), 8, dt, 0, 5, COMM_WORLD).unwrap();
        }
        engine::finalize().unwrap();
    });
    for o in &outcomes {
        assert!(o.is_ok());
    }
    assert!(trace.is_empty(), "trace-off job recorded {} rank buffers", trace.len());
}

/// A rendezvous-sized traced transfer must surface the protocol's
/// control events — RTS on the sender, CTS on the receiver.
#[test]
fn traced_rendezvous_shows_protocol_events() {
    use mpi_abi::core::reserved::COMM_WORLD;
    use mpi_abi::core::{datatype, engine};
    let spec = JobSpec::new(2).with_trace(true).with_rndv_threshold(1024);
    let (outcomes, trace) = run_job_traced(spec, |rank| {
        engine::init().unwrap();
        let dt = datatype::builtin_id_of_abi(mpi_abi::abi::datatypes::MPI_BYTE).unwrap();
        let mut buf = vec![0u8; 1 << 16];
        if rank == 0 {
            engine::send(
                buf.as_ptr(),
                1 << 16,
                dt,
                1,
                3,
                COMM_WORLD,
                engine::SendMode::Standard,
            )
            .unwrap();
        } else {
            engine::recv(buf.as_mut_ptr(), 1 << 16, dt, 0, 3, COMM_WORLD).unwrap();
        }
        engine::finalize().unwrap();
    });
    for o in &outcomes {
        assert!(o.is_ok());
    }
    let events_of = |rank: usize| {
        trace
            .iter()
            .find(|(r, _)| *r == rank)
            .map(|(_, e)| e.as_slice())
            .unwrap_or(&[])
    };
    assert!(
        events_of(0).iter().any(|e| matches!(e.kind, TraceKind::Rts)),
        "sender has no RTS event"
    );
    assert!(
        events_of(1).iter().any(|e| matches!(e.kind, TraceKind::Cts)),
        "receiver has no CTS event"
    );
}
