//! Integer constants of the standard ABI (§5.4).
//!
//! Design rules from the paper, enforced by tests:
//!
//! * Special-value integer constants are **unique negative numbers**, so an
//!   implementation can tell a user *by name* which constant they passed in
//!   the wrong slot (e.g. `MPI_ANY_TAG` as a rank).
//! * No constant exceeds 32767 (`INT_MAX` floor guaranteed by C).
//! * XOR-combinable mode constants are distinct **powers of two**.
//! * String-length constants are usable as array sizes; the largest known
//!   implementation values were chosen (8192 for the library version
//!   string, as MPICH uses).
//! * Predefined attribute callbacks: `0x0` for the NULL_COPY/DELETE
//!   functions, `0xD` for DUP functions.

use crate::abi::types::Aint;

// --- Unique negative special values ----------------------------------------

/// The standard-ABI `MPI_ANY_SOURCE` constant.
pub const MPI_ANY_SOURCE: i32 = -101;
/// The standard-ABI `MPI_ANY_TAG` constant.
pub const MPI_ANY_TAG: i32 = -102;
/// The standard-ABI `MPI_PROC_NULL` constant.
pub const MPI_PROC_NULL: i32 = -103;
/// The standard-ABI `MPI_ROOT` constant.
pub const MPI_ROOT: i32 = -104;
/// The standard-ABI `MPI_UNDEFINED` constant.
pub const MPI_UNDEFINED: i32 = -105;
/// The standard-ABI `MPI_KEYVAL_INVALID` constant.
pub const MPI_KEYVAL_INVALID: i32 = -106;
/// The standard-ABI `MPI_ERR_IN_STATUS_VAL` constant.
pub const MPI_ERR_IN_STATUS_VAL: i32 = -107;
/// The standard-ABI `MPI_COMM_TYPE_SHARED` split-type constant
/// (`MPI_Comm_split_type`; implementations number it differently —
/// MPICH 1, Open MPI 0 — so it translates at ABI boundaries like any
/// special int).
pub const MPI_COMM_TYPE_SHARED: i32 = 1;

/// All named special integer constants (for error reporting by name).
pub const SPECIAL_INTS: &[(&str, i32)] = &[
    ("MPI_ANY_SOURCE", MPI_ANY_SOURCE),
    ("MPI_ANY_TAG", MPI_ANY_TAG),
    ("MPI_PROC_NULL", MPI_PROC_NULL),
    ("MPI_ROOT", MPI_ROOT),
    ("MPI_UNDEFINED", MPI_UNDEFINED),
    ("MPI_KEYVAL_INVALID", MPI_KEYVAL_INVALID),
];

/// Look up a special constant by value — the §5.4 diagnosability property.
pub fn special_int_name(v: i32) -> Option<&'static str> {
    SPECIAL_INTS.iter().find(|&&(_, x)| x == v).map(|&(n, _)| n)
}

// --- Buffer address constants ----------------------------------------------

/// `MPI_BOTTOM`: must be distinguishable from any user buffer. The zero
/// address qualifies (and matches existing practice).
pub const MPI_BOTTOM: usize = 0;
/// `MPI_IN_PLACE`: a special address that can never be a user buffer; we
/// use 1 (an unaligned, unmapped address on all relevant platforms).
pub const MPI_IN_PLACE: usize = 1;
/// `MPI_STATUS_IGNORE` / `MPI_STATUSES_IGNORE` as special pointers.
pub const MPI_STATUS_IGNORE: usize = 2;
/// The standard-ABI `MPI_STATUSES_IGNORE` constant.
pub const MPI_STATUSES_IGNORE: usize = 3;

// --- String lengths (usable as array dimensions) -----------------------------

/// The standard-ABI `MPI_MAX_PROCESSOR_NAME` constant.
pub const MPI_MAX_PROCESSOR_NAME: usize = 256;
/// The standard-ABI `MPI_MAX_ERROR_STRING` constant.
pub const MPI_MAX_ERROR_STRING: usize = 512;
/// The standard-ABI `MPI_MAX_OBJECT_NAME` constant.
pub const MPI_MAX_OBJECT_NAME: usize = 128;
/// The standard-ABI `MPI_MAX_LIBRARY_VERSION_STRING` constant.
pub const MPI_MAX_LIBRARY_VERSION_STRING: usize = 8192;
/// The standard-ABI `MPI_MAX_INFO_KEY` constant.
pub const MPI_MAX_INFO_KEY: usize = 256;
/// The standard-ABI `MPI_MAX_INFO_VAL` constant.
pub const MPI_MAX_INFO_VAL: usize = 1024;
/// The standard-ABI `MPI_MAX_PORT_NAME` constant.
pub const MPI_MAX_PORT_NAME: usize = 1024;
/// The standard-ABI `MPI_MAX_DATAREP_STRING` constant.
pub const MPI_MAX_DATAREP_STRING: usize = 128;

// --- XOR-combinable assertion/mode constants (powers of two) -----------------

/// The standard-ABI `MPI_MODE_NOCHECK` constant.
pub const MPI_MODE_NOCHECK: i32 = 1024;
/// The standard-ABI `MPI_MODE_NOSTORE` constant.
pub const MPI_MODE_NOSTORE: i32 = 2048;
/// The standard-ABI `MPI_MODE_NOPUT` constant.
pub const MPI_MODE_NOPUT: i32 = 4096;
/// The standard-ABI `MPI_MODE_NOPRECEDE` constant.
pub const MPI_MODE_NOPRECEDE: i32 = 8192;
/// The standard-ABI `MPI_MODE_NOSUCCEED` constant.
pub const MPI_MODE_NOSUCCEED: i32 = 16384;

/// The standard-ABI `XOR_MODES` constant.
pub const XOR_MODES: &[(&str, i32)] = &[
    ("MPI_MODE_NOCHECK", MPI_MODE_NOCHECK),
    ("MPI_MODE_NOSTORE", MPI_MODE_NOSTORE),
    ("MPI_MODE_NOPUT", MPI_MODE_NOPUT),
    ("MPI_MODE_NOPRECEDE", MPI_MODE_NOPRECEDE),
    ("MPI_MODE_NOSUCCEED", MPI_MODE_NOSUCCEED),
];

// --- RMA lock types (§5.4) ----------------------------------------------------

/// The standard-ABI `MPI_LOCK_EXCLUSIVE` constant. Implementations number
/// these differently (MPICH: 234/235, Open MPI: 1/2); the standard ABI
/// pins the small values.
pub const MPI_LOCK_EXCLUSIVE: i32 = 1;
/// The standard-ABI `MPI_LOCK_SHARED` constant.
pub const MPI_LOCK_SHARED: i32 = 2;

// --- Thread levels (ordered comparison required by MPI) ----------------------

/// The standard-ABI `MPI_THREAD_SINGLE` constant.
pub const MPI_THREAD_SINGLE: i32 = 0;
/// The standard-ABI `MPI_THREAD_FUNNELED` constant.
pub const MPI_THREAD_FUNNELED: i32 = 1;
/// The standard-ABI `MPI_THREAD_SERIALIZED` constant.
pub const MPI_THREAD_SERIALIZED: i32 = 2;
/// The standard-ABI `MPI_THREAD_MULTIPLE` constant.
pub const MPI_THREAD_MULTIPLE: i32 = 3;

// --- Comparison results ------------------------------------------------------

/// The standard-ABI `MPI_IDENT` constant.
pub const MPI_IDENT: i32 = 0;
/// The standard-ABI `MPI_CONGRUENT` constant.
pub const MPI_CONGRUENT: i32 = 1;
/// The standard-ABI `MPI_SIMILAR` constant.
pub const MPI_SIMILAR: i32 = 2;
/// The standard-ABI `MPI_UNEQUAL` constant.
pub const MPI_UNEQUAL: i32 = 3;

// --- Type combiners (MPI_Type_get_envelope) ----------------------------------

/// The standard-ABI `MPI_COMBINER_NAMED` constant.
pub const MPI_COMBINER_NAMED: i32 = 1;
/// The standard-ABI `MPI_COMBINER_DUP` constant.
pub const MPI_COMBINER_DUP: i32 = 2;
/// The standard-ABI `MPI_COMBINER_CONTIGUOUS` constant.
pub const MPI_COMBINER_CONTIGUOUS: i32 = 3;
/// The standard-ABI `MPI_COMBINER_VECTOR` constant.
pub const MPI_COMBINER_VECTOR: i32 = 4;
/// The standard-ABI `MPI_COMBINER_HVECTOR` constant.
pub const MPI_COMBINER_HVECTOR: i32 = 5;
/// The standard-ABI `MPI_COMBINER_INDEXED` constant.
pub const MPI_COMBINER_INDEXED: i32 = 6;
/// The standard-ABI `MPI_COMBINER_HINDEXED` constant.
pub const MPI_COMBINER_HINDEXED: i32 = 7;
/// The standard-ABI `MPI_COMBINER_INDEXED_BLOCK` constant.
pub const MPI_COMBINER_INDEXED_BLOCK: i32 = 8;
/// The standard-ABI `MPI_COMBINER_HINDEXED_BLOCK` constant.
pub const MPI_COMBINER_HINDEXED_BLOCK: i32 = 9;
/// The standard-ABI `MPI_COMBINER_STRUCT` constant.
pub const MPI_COMBINER_STRUCT: i32 = 10;
/// The standard-ABI `MPI_COMBINER_SUBARRAY` constant.
pub const MPI_COMBINER_SUBARRAY: i32 = 11;
/// The standard-ABI `MPI_COMBINER_DARRAY` constant.
pub const MPI_COMBINER_DARRAY: i32 = 12;
/// The standard-ABI `MPI_COMBINER_RESIZED` constant.
pub const MPI_COMBINER_RESIZED: i32 = 13;

// --- Predefined attribute callbacks (§5.4) -----------------------------------

/// `MPI_COMM_NULL_COPY_FN`, `MPI_TYPE_NULL_COPY_FN`, … = `0x0`.
pub const MPI_NULL_COPY_FN: usize = 0x0;
/// `MPI_COMM_NULL_DELETE_FN`, … = `0x0`.
pub const MPI_NULL_DELETE_FN: usize = 0x0;
/// `MPI_COMM_DUP_FN`, `MPI_TYPE_DUP_FN`, … = `0xD`.
pub const MPI_DUP_FN: usize = 0xD;

// --- Predefined attribute keys -----------------------------------------------

/// The standard-ABI `MPI_TAG_UB` constant.
pub const MPI_TAG_UB: i32 = -201;
/// The standard-ABI `MPI_HOST` constant.
pub const MPI_HOST: i32 = -202;
/// The standard-ABI `MPI_IO` constant.
pub const MPI_IO: i32 = -203;
/// The standard-ABI `MPI_WTIME_IS_GLOBAL` constant.
pub const MPI_WTIME_IS_GLOBAL: i32 = -204;
/// The standard-ABI `MPI_UNIVERSE_SIZE` constant.
pub const MPI_UNIVERSE_SIZE: i32 = -205;
/// The standard-ABI `MPI_LASTUSEDCODE` constant.
pub const MPI_LASTUSEDCODE: i32 = -206;
/// The standard-ABI `MPI_APPNUM` constant.
pub const MPI_APPNUM: i32 = -207;

/// The value our implementations report for the `MPI_TAG_UB` attribute.
pub const TAG_UB_VALUE: Aint = 0x00FF_FFFF;

/// Version reported by `MPI_Get_version` for this ABI.
pub const MPI_VERSION: i32 = 4;
/// The standard-ABI `MPI_SUBVERSION` constant.
pub const MPI_SUBVERSION: i32 = 1;
/// The ABI's own version (would be `MPI_Abi_get_version` in the proposal).
pub const MPI_ABI_VERSION: i32 = 1;
/// The standard-ABI `MPI_ABI_SUBVERSION` constant.
pub const MPI_ABI_SUBVERSION: i32 = 0;

// --- Tools interface (MPI_T, §5.4 zero-page additions) -------------------------

/// The standard-ABI `MPI_T_VERBOSITY_USER_BASIC` constant. Verbosity
/// levels are ordered and contiguous so tools can range-filter.
pub const MPI_T_VERBOSITY_USER_BASIC: i32 = 1;
/// The standard-ABI `MPI_T_VERBOSITY_USER_DETAIL` constant.
pub const MPI_T_VERBOSITY_USER_DETAIL: i32 = 2;
/// The standard-ABI `MPI_T_VERBOSITY_USER_ALL` constant.
pub const MPI_T_VERBOSITY_USER_ALL: i32 = 3;
/// The standard-ABI `MPI_T_VERBOSITY_TUNER_BASIC` constant.
pub const MPI_T_VERBOSITY_TUNER_BASIC: i32 = 4;
/// The standard-ABI `MPI_T_VERBOSITY_TUNER_DETAIL` constant.
pub const MPI_T_VERBOSITY_TUNER_DETAIL: i32 = 5;
/// The standard-ABI `MPI_T_VERBOSITY_TUNER_ALL` constant.
pub const MPI_T_VERBOSITY_TUNER_ALL: i32 = 6;
/// The standard-ABI `MPI_T_VERBOSITY_MPIDEV_BASIC` constant.
pub const MPI_T_VERBOSITY_MPIDEV_BASIC: i32 = 7;
/// The standard-ABI `MPI_T_VERBOSITY_MPIDEV_DETAIL` constant.
pub const MPI_T_VERBOSITY_MPIDEV_DETAIL: i32 = 8;
/// The standard-ABI `MPI_T_VERBOSITY_MPIDEV_ALL` constant.
pub const MPI_T_VERBOSITY_MPIDEV_ALL: i32 = 9;

/// The standard-ABI `MPI_T_BIND_NO_OBJECT` constant: every variable this
/// engine exports is bound to the rank, not to an MPI object.
pub const MPI_T_BIND_NO_OBJECT: i32 = 0;

/// The standard-ABI `MPI_T_SCOPE_CONSTANT` constant.
pub const MPI_T_SCOPE_CONSTANT: i32 = 0;
/// The standard-ABI `MPI_T_SCOPE_READONLY` constant.
pub const MPI_T_SCOPE_READONLY: i32 = 1;
/// The standard-ABI `MPI_T_SCOPE_LOCAL` constant: writable, and the
/// write need not be uniform across ranks.
pub const MPI_T_SCOPE_LOCAL: i32 = 2;
/// The standard-ABI `MPI_T_SCOPE_GROUP` constant.
pub const MPI_T_SCOPE_GROUP: i32 = 3;
/// The standard-ABI `MPI_T_SCOPE_GROUP_EQ` constant.
pub const MPI_T_SCOPE_GROUP_EQ: i32 = 4;
/// The standard-ABI `MPI_T_SCOPE_ALL` constant.
pub const MPI_T_SCOPE_ALL: i32 = 5;
/// The standard-ABI `MPI_T_SCOPE_ALL_EQ` constant.
pub const MPI_T_SCOPE_ALL_EQ: i32 = 6;

/// The standard-ABI `MPI_T_PVAR_CLASS_COUNTER` constant: monotonically
/// increasing; sessions read it relative to a per-handle baseline.
pub const MPI_T_PVAR_CLASS_COUNTER: i32 = 1;
/// The standard-ABI `MPI_T_PVAR_CLASS_LEVEL` constant: an instantaneous
/// quantity (queue depth); read absolute, reset is a no-op.
pub const MPI_T_PVAR_CLASS_LEVEL: i32 = 2;
/// The standard-ABI `MPI_T_PVAR_CLASS_HIGHWATERMARK` constant.
pub const MPI_T_PVAR_CLASS_HIGHWATERMARK: i32 = 3;

/// All named MPI_T constants (SPEC table inventory + diagnostics).
pub const MPI_T_CONSTANTS: &[(&str, i32)] = &[
    ("MPI_T_VERBOSITY_USER_BASIC", MPI_T_VERBOSITY_USER_BASIC),
    ("MPI_T_VERBOSITY_USER_DETAIL", MPI_T_VERBOSITY_USER_DETAIL),
    ("MPI_T_VERBOSITY_USER_ALL", MPI_T_VERBOSITY_USER_ALL),
    ("MPI_T_VERBOSITY_TUNER_BASIC", MPI_T_VERBOSITY_TUNER_BASIC),
    ("MPI_T_VERBOSITY_TUNER_DETAIL", MPI_T_VERBOSITY_TUNER_DETAIL),
    ("MPI_T_VERBOSITY_TUNER_ALL", MPI_T_VERBOSITY_TUNER_ALL),
    ("MPI_T_VERBOSITY_MPIDEV_BASIC", MPI_T_VERBOSITY_MPIDEV_BASIC),
    ("MPI_T_VERBOSITY_MPIDEV_DETAIL", MPI_T_VERBOSITY_MPIDEV_DETAIL),
    ("MPI_T_VERBOSITY_MPIDEV_ALL", MPI_T_VERBOSITY_MPIDEV_ALL),
    ("MPI_T_BIND_NO_OBJECT", MPI_T_BIND_NO_OBJECT),
    ("MPI_T_SCOPE_CONSTANT", MPI_T_SCOPE_CONSTANT),
    ("MPI_T_SCOPE_READONLY", MPI_T_SCOPE_READONLY),
    ("MPI_T_SCOPE_LOCAL", MPI_T_SCOPE_LOCAL),
    ("MPI_T_SCOPE_GROUP", MPI_T_SCOPE_GROUP),
    ("MPI_T_SCOPE_GROUP_EQ", MPI_T_SCOPE_GROUP_EQ),
    ("MPI_T_SCOPE_ALL", MPI_T_SCOPE_ALL),
    ("MPI_T_SCOPE_ALL_EQ", MPI_T_SCOPE_ALL_EQ),
    ("MPI_T_PVAR_CLASS_COUNTER", MPI_T_PVAR_CLASS_COUNTER),
    ("MPI_T_PVAR_CLASS_LEVEL", MPI_T_PVAR_CLASS_LEVEL),
    ("MPI_T_PVAR_CLASS_HIGHWATERMARK", MPI_T_PVAR_CLASS_HIGHWATERMARK),
];

// --- Whole-ABI inventory helpers ----------------------------------------------

/// Every predefined handle constant in the ABI (ops + handles + datatypes),
/// used by inventory tests and the `abi_inspector` example.
pub fn all_predefined_handles() -> Vec<(&'static str, usize)> {
    let mut v = Vec::new();
    v.extend_from_slice(crate::abi::ops::PREDEFINED_OPS);
    v.extend_from_slice(crate::abi::handles::PREDEFINED_HANDLES);
    v.extend_from_slice(crate::abi::datatypes::PREDEFINED_DATATYPES);
    v
}

/// Resolve any predefined handle value to its MPI name.
pub fn handle_name(value: usize) -> Option<&'static str> {
    all_predefined_handles()
        .into_iter()
        .find(|&(_, v)| v == value)
        .map(|(n, _)| n)
}

/// Resolve an op constant to its name (fast path for A.1 values only).
pub fn op_name(value: usize) -> Option<&'static str> {
    crate::abi::ops::PREDEFINED_OPS
        .iter()
        .find(|&&(_, v)| v == value)
        .map(|&(n, _)| n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn special_ints_unique_and_negative() {
        let mut seen = std::collections::HashSet::new();
        for &(name, v) in SPECIAL_INTS {
            assert!(v < 0, "{name} must be negative");
            assert!(seen.insert(v), "{name} duplicates another constant");
        }
    }

    #[test]
    fn special_int_lookup_by_value() {
        // The paper's diagnosability example: user passes MPI_ANY_TAG as a
        // rank — the implementation can name the mistake.
        assert_eq!(special_int_name(MPI_ANY_TAG), Some("MPI_ANY_TAG"));
        assert_eq!(special_int_name(MPI_ANY_SOURCE), Some("MPI_ANY_SOURCE"));
        assert_eq!(special_int_name(-1), None);
    }

    #[test]
    fn constants_fit_portable_int() {
        // §5.4: integer constants may not exceed 32767.
        for &(_, v) in XOR_MODES {
            assert!(v <= 32767);
        }
        assert!(MPI_MAX_LIBRARY_VERSION_STRING <= 32767);
    }

    #[test]
    fn modes_are_distinct_powers_of_two() {
        let mut acc = 0i32;
        for &(name, v) in XOR_MODES {
            assert_eq!(v & (v - 1), 0, "{name} not a power of two");
            assert_eq!(acc & v, 0, "{name} overlaps another mode");
            acc |= v;
        }
        // XOR composition roundtrips.
        let combined = MPI_MODE_NOCHECK ^ MPI_MODE_NOPUT;
        assert_ne!(combined & MPI_MODE_NOCHECK, 0);
        assert_eq!(combined & MPI_MODE_NOSTORE, 0);
    }

    #[test]
    fn buffer_constants_are_not_plausible_buffers() {
        // Must be distinguishable from user buffers: all in the zero page.
        for v in [MPI_BOTTOM, MPI_IN_PLACE, MPI_STATUS_IGNORE, MPI_STATUSES_IGNORE] {
            assert!(v < 4096);
        }
        // And mutually distinct.
        let s: std::collections::HashSet<_> =
            [MPI_BOTTOM, MPI_IN_PLACE, MPI_STATUS_IGNORE, MPI_STATUSES_IGNORE].into();
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn dup_fn_is_0xd() {
        assert_eq!(MPI_DUP_FN, 0xD);
        assert_eq!(MPI_NULL_COPY_FN, 0x0);
    }

    #[test]
    fn thread_levels_ordered() {
        assert!(MPI_THREAD_SINGLE < MPI_THREAD_FUNNELED);
        assert!(MPI_THREAD_FUNNELED < MPI_THREAD_SERIALIZED);
        assert!(MPI_THREAD_SERIALIZED < MPI_THREAD_MULTIPLE);
    }

    #[test]
    fn attr_keys_unique_vs_special_ints() {
        let keys = [MPI_TAG_UB, MPI_HOST, MPI_IO, MPI_WTIME_IS_GLOBAL, MPI_UNIVERSE_SIZE];
        for k in keys {
            assert!(special_int_name(k).is_none(), "attr key {k} collides");
        }
    }

    #[test]
    fn mpi_t_verbosity_ordered_and_contiguous() {
        // Tools range-filter on verbosity; the nine levels must be 1..=9.
        let levels = [
            MPI_T_VERBOSITY_USER_BASIC,
            MPI_T_VERBOSITY_USER_DETAIL,
            MPI_T_VERBOSITY_USER_ALL,
            MPI_T_VERBOSITY_TUNER_BASIC,
            MPI_T_VERBOSITY_TUNER_DETAIL,
            MPI_T_VERBOSITY_TUNER_ALL,
            MPI_T_VERBOSITY_MPIDEV_BASIC,
            MPI_T_VERBOSITY_MPIDEV_DETAIL,
            MPI_T_VERBOSITY_MPIDEV_ALL,
        ];
        for (i, v) in levels.iter().enumerate() {
            assert_eq!(*v, i as i32 + 1);
        }
    }

    #[test]
    fn mpi_t_scopes_distinct_and_small() {
        let scopes = [
            MPI_T_SCOPE_CONSTANT,
            MPI_T_SCOPE_READONLY,
            MPI_T_SCOPE_LOCAL,
            MPI_T_SCOPE_GROUP,
            MPI_T_SCOPE_GROUP_EQ,
            MPI_T_SCOPE_ALL,
            MPI_T_SCOPE_ALL_EQ,
        ];
        let set: std::collections::HashSet<_> = scopes.into();
        assert_eq!(set.len(), scopes.len());
        for s in scopes {
            assert!((0..=32767).contains(&s));
        }
        // The inventory covers every named constant exactly once.
        let names: std::collections::HashSet<_> =
            MPI_T_CONSTANTS.iter().map(|&(n, _)| n).collect();
        assert_eq!(names.len(), MPI_T_CONSTANTS.len());
    }

    #[test]
    fn string_lengths_match_largest_known() {
        // §5.4: the largest known implementation values were chosen; MPICH's
        // 8192-byte library version string is called out explicitly.
        assert_eq!(MPI_MAX_LIBRARY_VERSION_STRING, 8192);
        assert!(MPI_MAX_ERROR_STRING >= 256);
    }
}
