"""L2: the JAX compute graph the MPI system serves — a data-parallel MLP
training step whose dense layers run through the L1 Pallas matmul kernel.

Two jitted entry points are AOT-lowered by ``aot.py``:

* ``grad_step(w1, b1, w2, b2, x, y) -> (loss, g_w1, g_b1, g_w2, g_b2)`` —
  the per-rank forward+backward. Gradients then cross ranks through
  ``MPI_Allreduce`` on the Rust side (L3), so this function must NOT
  embed any collective.
* ``sgd_update(w1, b1, w2, b2, g1..g4, lr) -> (w1', b1', w2', b2')`` —
  the optimizer step applied after gradient averaging.

Dims are multiples of 128 (the MXU tile edge): D=256 features, H=256
hidden, batch 128. Regression with MSE loss on synthetic data.
"""

import jax
import jax.numpy as jnp

from .kernels.matmul import dense

# Model geometry — all MXU-tile multiples.
BATCH = 128
D_IN = 256
D_HID = 256
D_OUT = 128  # output padded to a tile; loss masks to the first column


def init_params(seed: int = 0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w1 = jax.random.normal(k1, (D_IN, D_HID), jnp.float32) * (1.0 / jnp.sqrt(D_IN))
    b1 = jnp.zeros((D_HID,), jnp.float32)
    w2 = jax.random.normal(k2, (D_HID, D_OUT), jnp.float32) * (1.0 / jnp.sqrt(D_HID))
    b2 = jnp.zeros((D_OUT,), jnp.float32)
    return w1, b1, w2, b2


def synthetic_batch(seed: int):
    """Deterministic synthetic regression data: y = f(x) for a fixed
    random teacher; every rank derives its shard from its own seed."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1000))
    x = jax.random.normal(k1, (BATCH, D_IN), jnp.float32)
    teacher = jax.random.normal(k2, (D_IN,), jnp.float32)
    y = jnp.tanh(x @ teacher)  # scalar target per row
    return x, y


def _forward(w1, b1, w2, b2, x):
    h = jnp.tanh(dense(x, w1, b1))
    out = dense(h, w2, b2)
    return out[:, 0]  # first column is the regression head


def _loss(w1, b1, w2, b2, x, y):
    pred = _forward(w1, b1, w2, b2, x)
    return jnp.mean((pred - y) ** 2)


@jax.jit
def grad_step(w1, b1, w2, b2, x, y):
    loss, grads = jax.value_and_grad(_loss, argnums=(0, 1, 2, 3))(w1, b1, w2, b2, x, y)
    return (loss, *grads)


@jax.jit
def sgd_update(w1, b1, w2, b2, g1, g2, g3, g4, lr):
    return (
        w1 - lr * g1,
        b1 - lr * g2,
        w2 - lr * g3,
        b2 - lr * g4,
    )


def example_args_grad_step():
    w1, b1, w2, b2 = init_params()
    x, y = synthetic_batch(0)
    return (w1, b1, w2, b2, x, y)


def example_args_sgd_update():
    w1, b1, w2, b2 = init_params()
    z = (jnp.zeros_like(w1), jnp.zeros_like(b1), jnp.zeros_like(w2), jnp.zeros_like(b2))
    lr = jnp.float32(0.05)
    return (w1, b1, w2, b2, *z, lr)
