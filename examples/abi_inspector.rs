//! ABI inspector: dump the standard ABI's constant tables and demonstrate
//! the bit-level properties of the Huffman handle encoding (Appendix A).
//!
//! ```bash
//! cargo run --release --example abi_inspector
//! ```

use mpi_abi::abi;
use mpi_abi::abi::huffman::{datatype_class, decode, fixed_size_of, DatatypeClass, HandleKind};

fn main() {
    println!("standard MPI ABI — {}", abi::AbiVariant::native());
    println!(
        "MPI {}.{}  (ABI v{}.{})\n",
        abi::MPI_VERSION,
        abi::MPI_SUBVERSION,
        abi::MPI_ABI_VERSION,
        abi::MPI_ABI_SUBVERSION
    );

    println!("integer types:");
    println!("  MPI_Aint   = intptr_t ({} bits)", std::mem::size_of::<abi::Aint>() * 8);
    println!("  MPI_Offset = int64_t  ({} bits)", std::mem::size_of::<abi::Offset>() * 8);
    println!("  MPI_Count  = int64_t  ({} bits)", std::mem::size_of::<abi::Count>() * 8);
    println!(
        "  MPI_Status = {} bytes (3 public ints + 5 reserved)\n",
        std::mem::size_of::<abi::AbiStatus>()
    );

    println!("predefined handle constants (10-bit Huffman code, zero page):");
    println!("{:<28} {:>12}  {:<10} {}", "name", "binary", "kind", "decoded properties");
    let mut all = abi::all_predefined_handles();
    all.sort_by_key(|&(_, v)| v);
    for (name, v) in all {
        let kind = decode(v).unwrap();
        let props = match kind {
            HandleKind::Datatype => match datatype_class(v) {
                DatatypeClass::FixedSize => {
                    format!("fixed size: {} B (from bits 3..6)", fixed_size_of(v).unwrap())
                }
                DatatypeClass::VariableSize => {
                    match abi::datatypes::platform_size_of(v) {
                        Some(s) => format!("variable size (this platform: {s} B)"),
                        None => "no size (null/packed)".to_string(),
                    }
                }
                DatatypeClass::Reserved => "reserved".to_string(),
            },
            _ => String::new(),
        };
        println!("{name:<28} {v:#012b}  {kind:<10?} {props}");
    }

    println!("\nzero-page guarantee: max predefined value {:#x} <= {:#x}",
        abi::all_predefined_handles().iter().map(|&(_, v)| v).max().unwrap(),
        abi::huffman::HUFFMAN_MAX);

    println!("\ndiagnosable special constants (unique negatives, §5.4):");
    for &(name, v) in abi::SPECIAL_INTS {
        println!("  {v:>6}  {name}  (reverse lookup: {:?})", abi::special_int_name(v));
    }

    println!("\nerror classes ({}), MPI_SUCCESS = 0:", abi::ERROR_CLASSES.len());
    for &(name, v) in abi::ERROR_CLASSES.iter().take(8) {
        println!("  {v:>3}  {name:<22} \"{}\"", abi::error_string(v));
    }
    println!("  ... and {} more", abi::ERROR_CLASSES.len() - 8);

    // The cross-ABI comparison the paper's §3 tables make.
    println!("\nthe same constant in three ABIs:");
    println!("{:<16} {:>14} {:>18} {:>14}", "constant", "standard ABI", "mpich-like", "ompi-like");
    use mpi_abi::api::{Dt, MpiAbi};
    use mpi_abi::impls::{MpichAbi, OmpiAbi};
    let rows = [
        ("MPI_INT", Dt::Int),
        ("MPI_DOUBLE", Dt::Double),
        ("MPI_CHAR", Dt::Char),
    ];
    for (name, d) in rows {
        println!(
            "{:<16} {:>#14x} {:>#18x} {:>14p}",
            name,
            abi::handles::AbiDatatype(mpi_abi::api::dt_to_abi_const(d)).raw(),
            MpichAbi::datatype(d),
            OmpiAbi::datatype(d).0,
        );
    }
    println!(
        "{:<16} {:>14} {:>18} {:>14}",
        "MPI_ANY_SOURCE",
        mpi_abi::abi::constants::MPI_ANY_SOURCE,
        mpi_abi::impls::mpich::MPI_ANY_SOURCE,
        mpi_abi::impls::ompi::MPI_ANY_SOURCE,
    );
    println!("\n(an application binary bakes these in — which is exactly why an ABI standard is needed)");
}
