//! `abirun` — the launcher CLI (our `mpiexec`).
//!
//! ```text
//! abirun [-n RANKS] [--abi CONFIG] [--transport spsc|mutex] APP [ARGS]
//!
//! CONFIG: mpich | ompi | muk-mpich | muk-ompi | abi
//! APP:    hello | suite | osu_mbw_mr | osu_latency | halo | ddp | table1
//! ```

use mpi_abi::api::MpiAbi;
use mpi_abi::apps::{osu, with_abi, AbiApp, AbiConfig};
use mpi_abi::core::transport::TransportKind;
use mpi_abi::launcher::{run_job_ok, JobSpec};

fn usage() -> ! {
    eprintln!(
        "usage: abirun [-n RANKS] [--abi mpich|ompi|muk-mpich|muk-ompi|abi] \
         [--transport spsc|mutex] APP [ARGS]\n\
         apps: hello | suite | osu_mbw_mr | osu_latency | halo | ddp | table1"
    );
    std::process::exit(2);
}

struct Opts {
    ranks: usize,
    abi: AbiConfig,
    transport: TransportKind,
    app: String,
    args: Vec<String>,
}

fn parse_args() -> Opts {
    let mut ranks = 2;
    let mut abi = AbiConfig::NativeAbi;
    let mut transport = TransportKind::Spsc;
    let mut app = None;
    let mut rest = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "-n" | "--ranks" => {
                ranks = it.next().and_then(|v| v.parse().ok()).unwrap_or_else(|| usage())
            }
            "--abi" => {
                abi = it.next().and_then(|v| AbiConfig::parse(&v)).unwrap_or_else(|| usage())
            }
            "--transport" => {
                transport =
                    it.next().and_then(|v| TransportKind::parse(&v)).unwrap_or_else(|| usage())
            }
            "-h" | "--help" => usage(),
            _ if app.is_none() => app = Some(a),
            _ => rest.push(a),
        }
    }
    Opts { ranks, abi, transport, app: app.unwrap_or_else(|| usage()), args: rest }
}

struct AppRunner {
    opts: Opts,
}

impl AbiApp<()> for AppRunner {
    fn run<A: MpiAbi>(self) {
        let spec = JobSpec::new(self.opts.ranks).with_transport(self.opts.transport);
        match self.opts.app.as_str() {
            "hello" => {
                let out = run_job_ok(spec, |_| {
                    A::init();
                    let msg = mpi_abi::apps::hello::hello::<A>();
                    A::finalize();
                    msg
                });
                for line in out {
                    println!("{line}");
                }
            }
            "suite" => {
                let out = run_job_ok(spec, |rank| {
                    A::init();
                    let results = mpi_abi::testsuite::run_all::<A>(rank);
                    let report = mpi_abi::testsuite::report(A::NAME, &results);
                    let ok = results.iter().all(|r| r.passed);
                    A::finalize();
                    (report, ok)
                });
                println!("{}", out[0].0);
                if !out[0].1 {
                    std::process::exit(1);
                }
            }
            "osu_mbw_mr" => {
                let size: usize =
                    self.opts.args.first().and_then(|v| v.parse().ok()).unwrap_or(8);
                let out = run_job_ok(spec, |_| {
                    A::init();
                    let r = osu::mbw_mr::<A>(osu::MbwMrParams {
                        msg_size: size,
                        ..Default::default()
                    });
                    A::finalize();
                    r
                });
                println!(
                    "osu_mbw_mr [{}] {} B: {:.2} messages/second",
                    A::NAME,
                    size,
                    out[0]
                );
            }
            "osu_latency" => {
                let size: usize =
                    self.opts.args.first().and_then(|v| v.parse().ok()).unwrap_or(8);
                let out = run_job_ok(spec, |_| {
                    A::init();
                    let r = osu::latency::<A>(osu::LatencyParams {
                        msg_size: size,
                        ..Default::default()
                    });
                    A::finalize();
                    r
                });
                println!(
                    "osu_latency [{}] {} B: {:.1} ns one-way",
                    A::NAME,
                    size,
                    out[0] * 1e9
                );
            }
            "halo" => {
                // abirun halo [--mode sendrecv|persistent|rma] [--sessions]
                //             [--trace OUT.json] [--kill RANK[:TICKS]] [n] [iters]
                use mpi_abi::apps::halo::{jacobi, jacobi_ft, jacobi_sessions, HaloMode, HaloParams};
                let mut mode = HaloMode::Sendrecv;
                let mut sessions = false;
                let mut trace_path: Option<String> = None;
                let mut kill: Option<(usize, u64)> = None;
                let mut nums = Vec::new();
                let mut it = self.opts.args.iter();
                while let Some(a) = it.next() {
                    if a == "--mode" {
                        mode = it
                            .next()
                            .and_then(|v| HaloMode::parse(v))
                            .unwrap_or_else(|| usage());
                    } else if a == "--sessions" {
                        sessions = true;
                    } else if a == "--trace" {
                        trace_path = Some(it.next().cloned().unwrap_or_else(|| usage()));
                    } else if a == "--kill" {
                        // RANK[:TICKS] — the victim dies after TICKS
                        // progress-engine cycles (default 8: early in
                        // the first sweep).
                        let v = it.next().cloned().unwrap_or_else(|| usage());
                        let (r, t) = match v.split_once(':') {
                            Some((r, t)) => (r.parse().ok(), t.parse().ok()),
                            None => (v.parse().ok(), Some(8u64)),
                        };
                        kill = Some((
                            r.unwrap_or_else(|| usage()),
                            t.unwrap_or_else(|| usage()),
                        ));
                    } else if let Ok(v) = a.parse::<usize>() {
                        nums.push(v);
                    }
                }
                let n = nums.first().copied().unwrap_or(96);
                let iters = nums.get(1).copied().unwrap_or(50);
                if let Some((victim, ticks)) = kill {
                    // Fault-tolerant run: the victim dies mid-run; the
                    // survivors revoke, agree, shrink, re-decompose and
                    // converge. Every survivor must report the same
                    // shrunk size and a bitwise-identical residual.
                    if victim >= self.opts.ranks || self.opts.ranks < 2 {
                        eprintln!("abirun: --kill rank {victim} out of range");
                        std::process::exit(2);
                    }
                    let spec = spec.with_kill(victim, ticks);
                    let out = mpi_abi::launcher::run_job(spec, move |_| {
                        A::init();
                        let r = jacobi_ft::<A>(HaloParams { n, iters, mode });
                        // World was revoked during recovery, so the
                        // finalize barrier fails (returnably) — that is
                        // the expected ULFM endgame, not an error.
                        A::finalize();
                        r
                    });
                    let mut survivors = Vec::new();
                    let mut killed = Vec::new();
                    for (rank, o) in out.into_iter().enumerate() {
                        match o {
                            mpi_abi::launcher::RankOutcome::Ok(v) => survivors.push((rank, v)),
                            mpi_abi::launcher::RankOutcome::Killed => killed.push(rank),
                            other => {
                                eprintln!("abirun: rank {rank} failed: {other:?}");
                                std::process::exit(1);
                            }
                        }
                    }
                    assert_eq!(killed, vec![victim], "only the victim dies");
                    let (_, (shrunk, residual)) = survivors[0];
                    for &(rank, (s, r)) in &survivors {
                        assert_eq!(s, shrunk, "rank {rank} disagrees on shrunk size");
                        assert_eq!(
                            r.to_bits(),
                            residual.to_bits(),
                            "rank {rank} residual diverges bitwise"
                        );
                    }
                    println!(
                        "halo [{}] {n}x{n} grid, {iters} sweeps: rank {victim} killed at tick \
                         {ticks}, shrunk {} -> {shrunk} ranks, survivor residual {residual:.12}",
                        A::NAME,
                        self.opts.ranks,
                    );
                    return;
                }
                let spec = if trace_path.is_some() { spec.with_trace(true) } else { spec };
                let body = move |_: usize| {
                    if sessions {
                        // Sessions-only: no MPI_Init / MPI_Finalize at all.
                        let (_, global) = jacobi_sessions::<A>(HaloParams { n, iters, mode });
                        global
                    } else {
                        A::init();
                        let (_, global) = jacobi::<A>(HaloParams { n, iters, mode });
                        A::finalize();
                        global
                    }
                };
                let out = if let Some(path) = &trace_path {
                    let (outcomes, trace) = mpi_abi::launcher::run_job_traced(spec, body);
                    let events: usize = trace.iter().map(|(_, evs)| evs.len()).sum();
                    let json = mpi_abi::core::obs::chrome_trace_json(&trace);
                    std::fs::write(path, json).unwrap_or_else(|e| {
                        eprintln!("abirun: cannot write trace to {path}: {e}");
                        std::process::exit(1);
                    });
                    eprintln!("trace: {events} events from {} ranks -> {path}", trace.len());
                    outcomes.into_iter().map(|o| o.unwrap()).collect::<Vec<_>>()
                } else {
                    run_job_ok(spec, body)
                };
                println!(
                    "halo [{}] {}x{} grid, {} sweeps, mode {}{}: residual {:.12}",
                    A::NAME,
                    n,
                    n,
                    iters,
                    mode.name(),
                    if sessions { " (sessions-only)" } else { "" },
                    out[0]
                );
            }
            "ddp" => {
                let steps: usize =
                    self.opts.args.first().and_then(|v| v.parse().ok()).unwrap_or(40);
                let out = run_job_ok(spec, |_| {
                    A::init();
                    let r = mpi_abi::apps::ddp::train::<A>(mpi_abi::apps::ddp::DdpParams {
                        steps,
                        ..Default::default()
                    });
                    A::finalize();
                    (r.loss_curve, r.final_loss)
                });
                println!("ddp [{}] loss curve:", A::NAME);
                for (step, loss) in &out[0].0 {
                    println!("  step {step:4}  loss {loss:.6}");
                }
            }
            _ => usage(),
        }
    }
}

/// Table 1 reproduction: message rate across the five ABI configs and
/// both transports (also available as `cargo bench` message_rate).
fn table1(ranks: usize) {
    println!("Table 1 analogue: message rate (8-byte messages), {ranks} ranks");
    println!("{:<34} {:>18}", "MPI", "Messages/second");
    let rows: [(&str, AbiConfig, TransportKind); 5] = [
        ("impl-A mutex shm (\"Intel MPI\")", AbiConfig::Mpich, TransportKind::Mutex),
        ("+ Mukautuva", AbiConfig::MukMpich, TransportKind::Mutex),
        ("impl-A spsc shm (\"MPICH dev UCX\")", AbiConfig::Mpich, TransportKind::Spsc),
        ("+ Mukautuva", AbiConfig::MukMpich, TransportKind::Spsc),
        ("impl-A spsc, native std ABI", AbiConfig::NativeAbi, TransportKind::Spsc),
    ];
    struct Row {
        transport: TransportKind,
    }
    impl AbiApp<f64> for Row {
        fn run<A: MpiAbi>(self) -> f64 {
            let spec = JobSpec::new(2).with_transport(self.transport);
            let out = run_job_ok(spec, |_| {
                A::init();
                let r = osu::mbw_mr::<A>(Default::default());
                A::finalize();
                r
            });
            out[0]
        }
    }
    for (label, abi, transport) in rows {
        let rate = with_abi(abi, Row { transport });
        println!("{label:<34} {rate:>18.2}");
    }
}

fn main() {
    let opts = parse_args();
    if opts.app == "table1" {
        table1(opts.ranks);
        return;
    }
    let abi = opts.abi;
    with_abi(abi, AppRunner { opts });
}
