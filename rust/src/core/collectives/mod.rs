//! Collective operations, expressed as per-rank schedules over each
//! communicator's dedicated collective context plane.
//!
//! Algorithms: dissemination barrier, binomial-tree bcast/reduce,
//! reduce+bcast allreduce, linear (root-rooted) gather/scatter familes,
//! pairwise alltoall, linear scan. All collectives advance a per-comm
//! collective tag so consecutive collectives never cross-match.
//!
//! Every algorithm lives exactly once, as a schedule builder in
//! [`sched`]; the nonblocking entry points (`ibcast`, `iallreduce`, …)
//! return the schedule's request, and the blocking entry points are
//! `wait(i<coll>())` over the same schedules.

mod alltoall;
mod bcast_reduce;
mod gather_scatter;
pub mod sched;

pub use alltoall::{alltoall, alltoall_bytes, alltoallv, alltoallw, AlltoallwArgs};
pub use bcast_reduce::{allreduce, bcast, exscan, reduce, reduce_scatter_block, scan};
pub use gather_scatter::{allgather, allgatherv, gather, gatherv, scatter, scatterv};
pub use sched::{
    iallgather, iallgatherv, iallreduce, ialltoall, ialltoallv, ialltoallw, ibarrier, ibcast,
    iexscan, igather, igatherv, ireduce, ireduce_scatter_block, iscan, iscatter, iscatterv,
};
pub use sched::{
    allreduce_init, alltoall_init, barrier_init, bcast_init, gather_init, scatter_init,
    schedules_built,
};

use super::comm::{advance_coll_tag, comm_snapshot};
use super::request::{enqueue_send, progress};
use super::transport::{Envelope, MsgKind, Payload};
use super::world::{with_ctx, RankCtx};
use super::{err, CommId, DtId, MpiError, RC, ReqId};

/// Snapshot of what a collective needs: members, my comm rank, the
/// collective context id, and this collective's tag.
pub(crate) struct CollCtx {
    pub members: Vec<usize>,
    pub my_rank: usize,
    pub context: u32,
    pub tag: i32,
}

impl CollCtx {
    pub fn size(&self) -> usize {
        self.members.len()
    }
}

/// Begin a collective on `comm` (advances the collective sequence).
///
/// The returned tag is the collective's *base* tag; each collective may
/// use up to [`PHASES_PER_COLL`] consecutive tags (`base..base+32`) for
/// internal rounds (e.g. dissemination-barrier rounds), guaranteed not to
/// collide with neighbouring collectives on the same comm.
pub(crate) fn coll_begin(comm: CommId) -> RC<CollCtx> {
    let (members, my_rank, _p, context) = comm_snapshot(comm)?;
    let seq = advance_coll_tag(comm)?;
    Ok(CollCtx { members, my_rank, context, tag: (seq & 0xFF_FFFF) * PHASES_PER_COLL })
}

/// Tag slots reserved per collective for internal phases/rounds.
pub(crate) const PHASES_PER_COLL: i32 = 32;

/// Send raw bytes to comm rank `dst` on the collective plane.
pub(crate) fn coll_send(ctx: &RankCtx, cc: &CollCtx, dst: usize, payload: Payload) {
    let env = Envelope {
        src: ctx.rank as u32,
        context: cc.context,
        tag: cc.tag,
        kind: MsgKind::Eager,
        seq: 0,
        payload,
    };
    enqueue_send(ctx, cc.members[dst], env);
}

/// Blocking receive of raw bytes from comm rank `src` on the collective
/// plane (bypasses the request engine: collective internals own their
/// buffers).
pub(crate) fn coll_recv(ctx: &RankCtx, cc: &CollCtx, src: usize) -> Payload {
    let want_src = cc.members[src] as i32;
    loop {
        progress(ctx);
        // Exact (src, tag) probe of the unexpected index — O(1).
        if let Some(env) =
            ctx.state.borrow_mut().match_index.take_unexpected(cc.context, want_src, cc.tag)
        {
            return env.payload;
        }
        std::thread::yield_now();
    }
}

/// Block until the collective request `rid` completes, surfacing any
/// error class its schedule recorded. The blocking collectives are all
/// `submit schedule → wait_coll`.
pub(crate) fn wait_coll(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| {
        let st = super::request::wait_one(ctx, rid)?;
        if st.error != 0 {
            return Err(MpiError::new(st.error));
        }
        Ok(())
    })
}

/// `MPI_Barrier` = wait(`MPI_Ibarrier`): dissemination algorithm
/// (⌈log2 n⌉ rounds), one tag phase per round so a racing peer's later
/// round never cross-matches.
pub fn barrier(comm: CommId) -> RC<()> {
    wait_coll(sched::ibarrier(comm)?)
}

/// Engine-internal: broadcast a fixed byte buffer (used by comm creation
/// before the new comm exists).
pub fn bcast_bytes(buf: &mut [u8], root: usize, comm: CommId) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        bcast_bytes_cc(ctx, &cc, buf, root);
        Ok(())
    })
}

/// Binomial-tree byte broadcast over an existing CollCtx.
pub(crate) fn bcast_bytes_cc(ctx: &RankCtx, cc: &CollCtx, buf: &mut [u8], root: usize) {
    let n = cc.size();
    if n <= 1 {
        return;
    }
    // Virtual ranks with root at 0.
    let vrank = (cc.my_rank + n - root) % n;
    // Receive from parent (unless root).
    if vrank != 0 {
        let parent = parent_of(vrank);
        let parent_real = (parent + root) % n;
        let p = coll_recv(ctx, cc, parent_real);
        let data = p.as_slice();
        let take = data.len().min(buf.len());
        buf[..take].copy_from_slice(&data[..take]);
    }
    // Forward to children.
    for child in children_of(vrank, n) {
        let child_real = (child + root) % n;
        coll_send(ctx, cc, child_real, Payload::from_slice(buf));
    }
}

/// Engine-level `MPI_Allgatherv_c`: the embiggened allgatherv — per-rank
/// receive counts as `MPI_Count` and displacements as `MPI_Aint` (in
/// units of `recvtype` extent), so block `r` may start beyond 2 GiB.
/// Linear exchange on the collective plane: every rank contributes
/// `sendcount` items of `sendtype`; rank `r`'s block unpacks as
/// `recvcounts[r]` items of `recvtype` at
/// `recvbuf + displs[r] × extent(recvtype)`.
#[allow(clippy::too_many_arguments)]
pub fn allgatherv_c(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[i64],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        if recvcounts.len() < n || displs.len() < n {
            return Err(err!(MPI_ERR_COUNT));
        }
        if recvcounts.iter().take(n).any(|&c| c < 0) {
            return Err(err!(MPI_ERR_COUNT));
        }
        let (_, rext) = super::datatype::type_get_extent(recvtype)?;
        // Pack my contribution once; it both goes to every peer and
        // lands in my own block locally.
        let mine = {
            let t = ctx.tables.borrow();
            let mut v = Vec::new();
            super::datatype::pack::pack(&t.dtypes, sendbuf, sendcount, sendtype, &mut v)?;
            v
        };
        for r in 0..n {
            if r != cc.my_rank {
                coll_send(ctx, &cc, r, Payload::from_slice(&mine));
            }
        }
        {
            let t = ctx.tables.borrow();
            let dst = unsafe { recvbuf.offset(displs[cc.my_rank] * rext) };
            super::datatype::pack::unpack(
                &t.dtypes,
                &mine,
                dst,
                recvcounts[cc.my_rank] as usize,
                recvtype,
            )?;
        }
        for r in 0..n {
            if r == cc.my_rank {
                continue;
            }
            let p = coll_recv(ctx, &cc, r);
            let t = ctx.tables.borrow();
            let dst = unsafe { recvbuf.offset(displs[r] * rext) };
            super::datatype::pack::unpack(
                &t.dtypes,
                p.as_slice(),
                dst,
                recvcounts[r] as usize,
                recvtype,
            )?;
        }
        Ok(())
    })
}

/// Engine-internal: gather fixed-size byte blocks at `root`.
/// `send.len()` bytes from every rank land at `recv[r*send.len()..]`.
pub fn gather_bytes(send: &[u8], recv: &mut [u8], root: usize, comm: CommId) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let blk = send.len();
        if cc.my_rank == root {
            recv[root * blk..(root + 1) * blk].copy_from_slice(send);
            for r in 0..n {
                if r == root {
                    continue;
                }
                let p = coll_recv(ctx, &cc, r);
                recv[r * blk..r * blk + p.len().min(blk)]
                    .copy_from_slice(&p.as_slice()[..p.len().min(blk)]);
            }
        } else {
            coll_send(ctx, &cc, root, Payload::from_slice(send));
        }
        Ok(())
    })
}

/// Engine-internal: scatter variable-size blobs from `root`; returns this
/// rank's blob.
pub fn scatter_var_bytes(blobs: &[Vec<u8>], root: usize, comm: CommId) -> RC<Vec<u8>> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        if cc.my_rank == root {
            for r in 0..n {
                if r == root {
                    continue;
                }
                coll_send(ctx, &cc, r, Payload::from_slice(&blobs[r]));
            }
            Ok(blobs[root].clone())
        } else {
            Ok(coll_recv(ctx, &cc, root).as_slice().to_vec())
        }
    })
}

/// Binomial-tree helpers on virtual ranks (root = 0).
pub(crate) fn parent_of(vrank: usize) -> usize {
    debug_assert!(vrank != 0);
    vrank & (vrank - 1) // clear lowest set bit
}

pub(crate) fn children_of(vrank: usize, n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut bit = 1usize;
    // Children are vrank | bit for bits below the lowest set bit of vrank
    // (or all bits for root), while in range.
    let limit = if vrank == 0 { n.next_power_of_two() } else { vrank & vrank.wrapping_neg() };
    while bit < limit {
        let c = vrank | bit;
        if c < n && c != vrank {
            out.push(c);
        }
        bit <<= 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_tree_shape() {
        // n = 8: 0 -> {1, 2, 4}; 2 -> {3}; 4 -> {5, 6}; 6 -> {7}.
        assert_eq!(children_of(0, 8), vec![1, 2, 4]);
        assert_eq!(children_of(2, 8), vec![3]);
        assert_eq!(children_of(4, 8), vec![5, 6]);
        assert_eq!(children_of(6, 8), vec![7]);
        assert_eq!(children_of(7, 8), Vec::<usize>::new());
        for v in 1..8 {
            let p = parent_of(v);
            assert!(children_of(p, 8).contains(&v), "{p} must parent {v}");
        }
    }

    #[test]
    fn binomial_tree_nonpow2() {
        // n = 6: every non-root has a parent, all nodes covered exactly once.
        let n = 6;
        let mut seen = vec![false; n];
        seen[0] = true;
        let mut stack = vec![0usize];
        while let Some(v) = stack.pop() {
            for c in children_of(v, n) {
                assert!(!seen[c], "child {c} visited twice");
                seen[c] = true;
                stack.push(c);
            }
        }
        assert!(seen.iter().all(|&s| s), "all ranks covered: {seen:?}");
    }
}
