//! E2 — **Table 1**: message rate (8-byte messages, `osu_mbw_mr`) for the
//! five evaluation configurations.
//!
//! Paper rows (i7-1165G7, Linux 5.19):
//!
//! | MPI                    | Messages/second |
//! |------------------------|-----------------|
//! | Intel MPI 2021.9.0     |      4,658,939  |
//! | + Mukautuva            |      4,606,473  |  (−1.1%)
//! | MPICH dev UCX          |     13,643,117  |
//! | + Mukautuva            |     12,278,837  |  (−10.0%)
//! | MPICH dev UCX ABI      |     13,643,378  |  (+0.0%)
//!
//! Shape targets: transport choice dominates (≥2x), the native standard
//! ABI build is within noise of the implementation ABI, and Mukautuva
//! costs a tolerable single-digit-to-low-teens percentage.

use mpi_abi::api::MpiAbi;
use mpi_abi::apps::osu::{mbw_mr, MbwMrParams};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::bench::Table;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::launcher::{run_job_ok, JobSpec};

struct Row {
    transport: TransportKind,
    samples: usize,
}

impl AbiApp<f64> for Row {
    fn run<A: MpiAbi>(self) -> f64 {
        // Best-of-N to shed scheduler noise on the shared core.
        let mut best = 0.0f64;
        for _ in 0..self.samples {
            let out = run_job_ok(JobSpec::new(2).with_transport(self.transport), |_| {
                A::init();
                let r = mbw_mr::<A>(MbwMrParams::default());
                A::finalize();
                r
            });
            best = best.max(out[0]);
        }
        best
    }
}

fn main() {
    // The XLA offload is irrelevant at 8-byte messages; disable to keep
    // client init out of the timing.
    std::env::set_var("MPI_ABI_NO_XLA", "1");
    let samples = 7;
    println!("\nE2 — Table 1: osu_mbw_mr message rate (8-byte messages, 2 ranks, window 64)");
    let rows: [(&str, AbiConfig, TransportKind); 7] = [
        ("impl-A / mutex shm   (\"Intel MPI\")", AbiConfig::Mpich, TransportKind::Mutex),
        ("  + Mukautuva", AbiConfig::MukMpich, TransportKind::Mutex),
        ("impl-A / spsc shm    (\"MPICH dev UCX\")", AbiConfig::Mpich, TransportKind::Spsc),
        ("  + Mukautuva", AbiConfig::MukMpich, TransportKind::Spsc),
        ("impl-A / spsc, native std ABI (\"UCX ABI\")", AbiConfig::NativeAbi, TransportKind::Spsc),
        ("impl-B / spsc shm    (extra: ompi)", AbiConfig::Ompi, TransportKind::Spsc),
        ("  + Mukautuva", AbiConfig::MukOmpi, TransportKind::Spsc),
    ];
    let mut table = Table::new("Table 1 analogue", &["MPI", "Messages/second"]);
    let mut rates = Vec::new();
    for (label, abi, transport) in rows {
        let rate = with_abi(abi, Row { transport, samples });
        println!("{label:<44} {rate:>14.2} msg/s");
        table.row(&[label.to_string(), format!("{rate:.2}")]);
        rates.push(rate);
    }
    // Pre-index baseline rows (the seed's flat matcher, via the env
    // flag) so the indexed matching engine's speedup is in the table.
    std::env::set_var("MPI_ABI_FLAT_MATCH", "1");
    let flat_spsc =
        with_abi(AbiConfig::Mpich, Row { transport: TransportKind::Spsc, samples });
    let flat_mutex =
        with_abi(AbiConfig::Mpich, Row { transport: TransportKind::Mutex, samples });
    std::env::remove_var("MPI_ABI_FLAT_MATCH");
    for (label, rate) in [
        ("impl-A / spsc, MPI_ABI_FLAT_MATCH=1 (baseline)", flat_spsc),
        ("impl-A / mutex, MPI_ABI_FLAT_MATCH=1 (baseline)", flat_mutex),
    ] {
        println!("{label:<44} {rate:>14.2} msg/s");
        table.row(&[label.to_string(), format!("{rate:.2}")]);
    }
    println!("{}", table.render());
    println!(
        "index win: indexed matcher is {:.2}x (spsc) / {:.2}x (mutex) vs the flat baseline",
        rates[2] / flat_spsc,
        rates[0] / flat_mutex
    );

    // Shape checks against the paper.
    let (mutex_base, mutex_muk) = (rates[0], rates[1]);
    let (spsc_base, spsc_muk, spsc_abi) = (rates[2], rates[3], rates[4]);
    println!("shape checks (paper expectations):");
    println!(
        "  transport dominates: spsc/mutex = {:.2}x   (paper: 2.9x)",
        spsc_base / mutex_base
    );
    println!(
        "  native std ABI vs impl ABI: {:+.2}%        (paper: +0.002%)",
        (spsc_abi / spsc_base - 1.0) * 100.0
    );
    println!(
        "  Mukautuva cost on fast transport: {:+.2}%  (paper: -10.0%)",
        (spsc_muk / spsc_base - 1.0) * 100.0
    );
    println!(
        "  Mukautuva cost on slow transport: {:+.2}%  (paper: -1.1%)",
        (mutex_muk / mutex_base - 1.0) * 100.0
    );
}
