//! Hand-rolled measurement harness (criterion is not in the offline
//! crate set): warmup, timed iterations, robust statistics, and
//! criterion-style one-line reports. The [`harness`] submodule is the
//! grid runner behind the `abibench` binary (`BENCH_PR5.json`).

pub mod harness;

use std::time::Instant;

/// Summary statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    /// Mean seconds per iteration.
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub samples: usize,
}

impl Stats {
    fn from_samples(name: &str, mut samples: Vec<f64>) -> Stats {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        Stats {
            name: name.to_string(),
            mean,
            median: samples[n / 2],
            stddev: var.sqrt(),
            min: samples[0],
            max: samples[n - 1],
            samples: n,
        }
    }

    /// criterion-ish report line.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  (±{})",
            self.name,
            fmt_time(self.min),
            fmt_time(self.median),
            fmt_time(self.max),
            fmt_time(self.stddev),
        )
    }

    /// Iterations (or events) per second at the mean.
    pub fn rate(&self) -> f64 {
        1.0 / self.mean
    }
}

/// Human time formatting.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.2} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark a closure: `samples` timed samples of `iters_per_sample`
/// iterations each, after `warmup` untimed iterations.
pub fn bench(name: &str, warmup: usize, samples: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        out.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    Stats::from_samples(name, out)
}

/// Benchmark a closure that measures itself (returns seconds per event):
/// used for multi-rank benches where the timed region lives on rank 0.
pub fn bench_external(name: &str, samples: usize, mut f: impl FnMut() -> f64) -> Stats {
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        out.push(f());
    }
    Stats::from_samples(name, out)
}

/// Simple fixed-width table printer for paper-style tables.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n=== {} ===\n", self.title);
        let hdr: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect();
        out.push_str(&hdr.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            out.push_str(&cells.join("  "));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let s = bench("noop", 2, 5, 1000, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.mean > 0.0);
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(s.samples, 5);
        assert!(s.report().contains("noop"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains("s"));
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Demo", &["MPI", "Messages/second"]);
        t.row(&["impl-A".to_string(), "123".to_string()]);
        let r = t.render();
        assert!(r.contains("Demo") && r.contains("impl-A"));
    }
}
