//! All-to-all collectives, including the nonblocking `MPI_Ialltoallw` —
//! the paper's worst-case ABI-translation scenario (§6.2): a request that
//! owns *vectors of datatype handles* which a translation layer must
//! convert and keep alive until completion.

use super::{coll_begin, coll_recv, coll_send};
use crate::core::datatype::pack::{pack, unpack};
use crate::core::request::{new_request, post_recv, ReqKind, StatusCore};
use crate::core::transport::{Envelope, MsgKind, Payload};
use crate::core::world::{with_ctx, RankCtx};
use crate::core::{err, CommId, DtId, RC, ReqId};

fn pack_at(
    ctx: &RankCtx,
    buf: *const u8,
    byte_offset: isize,
    count: usize,
    dt: DtId,
) -> RC<Vec<u8>> {
    let t = ctx.tables.borrow();
    let src = unsafe { buf.offset(byte_offset) };
    let mut v = Vec::new();
    pack(&t.dtypes, src, count, dt, &mut v)?;
    Ok(v)
}

/// `MPI_Alltoall`.
#[allow(clippy::too_many_arguments)]
pub fn alltoall(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    let n = crate::core::comm::comm_size(comm)? as usize;
    let (sext, rext) = {
        let se = crate::core::datatype::type_get_extent(sendtype)?.1;
        let re = crate::core::datatype::type_get_extent(recvtype)?.1;
        (se, re)
    };
    let scounts = vec![sendcount; n];
    let sdispls: Vec<isize> = (0..n).map(|r| r as isize * sendcount as isize * sext).collect();
    let stypes = vec![sendtype; n];
    let rcounts = vec![recvcount; n];
    let rdispls: Vec<isize> = (0..n).map(|r| r as isize * recvcount as isize * rext).collect();
    let rtypes = vec![recvtype; n];
    let args = AlltoallwArgs {
        sendbuf,
        sendcounts: scounts,
        sdispls,
        sendtypes: stypes,
        recvbuf,
        recvcounts: rcounts,
        rdispls,
        recvtypes: rtypes,
    };
    alltoallw(&args, comm)
}

/// `MPI_Alltoallv` (displacements in type extents, MPI-style).
#[allow(clippy::too_many_arguments)]
pub fn alltoallv(
    sendbuf: *const u8,
    sendcounts: &[usize],
    sdispls_elems: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    rdispls_elems: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    let n = crate::core::comm::comm_size(comm)? as usize;
    let sext = crate::core::datatype::type_get_extent(sendtype)?.1;
    let rext = crate::core::datatype::type_get_extent(recvtype)?.1;
    let args = AlltoallwArgs {
        sendbuf,
        sendcounts: sendcounts.to_vec(),
        sdispls: sdispls_elems.iter().map(|&d| d * sext).collect(),
        sendtypes: vec![sendtype; n],
        recvbuf,
        recvcounts: recvcounts.to_vec(),
        rdispls: rdispls_elems.iter().map(|&d| d * rext).collect(),
        recvtypes: vec![recvtype; n],
    };
    alltoallw(&args, comm)
}

/// The `MPI_Alltoallw` argument bundle: per-peer counts, *byte*
/// displacements, and per-peer datatypes.
pub struct AlltoallwArgs {
    pub sendbuf: *const u8,
    pub sendcounts: Vec<usize>,
    pub sdispls: Vec<isize>,
    pub sendtypes: Vec<DtId>,
    pub recvbuf: *mut u8,
    pub recvcounts: Vec<usize>,
    pub rdispls: Vec<isize>,
    pub recvtypes: Vec<DtId>,
}

/// `MPI_Alltoallw` (blocking).
pub fn alltoallw(args: &AlltoallwArgs, comm: CommId) -> RC<()> {
    with_ctx(|ctx| {
        let rid = ialltoallw_impl(ctx, args, comm)?;
        crate::core::request::wait_one(ctx, rid)?;
        Ok(())
    })
}

/// `MPI_Ialltoallw`: returns a compound request completing when all
/// internal sends/recvs do.
pub fn ialltoallw(args: &AlltoallwArgs, comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| ialltoallw_impl(ctx, args, comm))
}

fn ialltoallw_impl(ctx: &RankCtx, args: &AlltoallwArgs, comm: CommId) -> RC<ReqId> {
    let cc = coll_begin(comm)?;
    let n = cc.size();
    if args.sendcounts.len() != n || args.recvcounts.len() != n {
        return Err(err!(MPI_ERR_COUNT));
    }
    let mut children = Vec::with_capacity(2 * n);
    // Post all receives first (so racing peers' eager sends match).
    for r in 0..n {
        if r == cc.my_rank {
            continue;
        }
        let dst = unsafe { args.recvbuf.offset(args.rdispls[r]) };
        let rid = post_recv(
            ctx,
            dst as usize,
            args.recvcounts[r],
            args.recvtypes[r],
            cc.members[r] as i32,
            cc.tag,
            cc.context,
        );
        children.push(rid);
    }
    // Send to every peer (eager — complete immediately).
    for r in 0..n {
        if r == cc.my_rank {
            // Self-exchange: local pack/unpack.
            let bytes = pack_at(ctx, args.sendbuf, args.sdispls[r], args.sendcounts[r],
                args.sendtypes[r])?;
            let t = ctx.tables.borrow();
            let dst = unsafe { args.recvbuf.offset(args.rdispls[r]) };
            unpack(&t.dtypes, &bytes, dst, args.recvcounts[r], args.recvtypes[r])?;
            continue;
        }
        let bytes =
            pack_at(ctx, args.sendbuf, args.sdispls[r], args.sendcounts[r], args.sendtypes[r])?;
        let env = Envelope {
            src: ctx.rank as u32,
            context: cc.context,
            tag: cc.tag,
            kind: MsgKind::Eager,
            seq: 0,
            payload: Payload::from_vec(bytes),
        };
        crate::core::request::enqueue_send(ctx, cc.members[r], env);
    }
    if children.is_empty() {
        // size-1 comm: complete immediately.
        return Ok(new_request(ctx, ReqKind::Send, Some(StatusCore::empty())));
    }
    Ok(new_request(ctx, ReqKind::Coll { children }, None))
}

/// `MPI_Ibarrier`-alike used by the test suite: a compound request over a
/// zero-byte alltoall (dissemination would need phase-aware children; an
/// all-to-all of empty messages is a correct, simpler barrier).
pub fn ibarrier(comm: CommId) -> RC<ReqId> {
    let n = crate::core::comm::comm_size(comm)? as usize;
    // Static empty buffers: no data moves, only synchronization.
    let args = AlltoallwArgs {
        sendbuf: std::ptr::NonNull::<u8>::dangling().as_ptr(),
        sendcounts: vec![0; n],
        sdispls: vec![0; n],
        sendtypes: vec![DtId(0); n],
        recvbuf: std::ptr::NonNull::<u8>::dangling().as_ptr(),
        recvcounts: vec![0; n],
        rdispls: vec![0; n],
        recvtypes: vec![DtId(0); n],
    };
    ialltoallw(&args, comm)
}

/// Byte-level alltoall used internally and by benches: every rank sends
/// `blk` bytes to every peer from `send[r*blk..]` into `recv[r*blk..]`.
pub fn alltoall_bytes(send: &[u8], recv: &mut [u8], blk: usize, comm: CommId) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        for r in 0..n {
            if r == cc.my_rank {
                recv[r * blk..(r + 1) * blk].copy_from_slice(&send[r * blk..(r + 1) * blk]);
            } else {
                coll_send(ctx, &cc, r, Payload::from_slice(&send[r * blk..(r + 1) * blk]));
            }
        }
        for r in 0..n {
            if r == cc.my_rank {
                continue;
            }
            let p = coll_recv(ctx, &cc, r);
            recv[r * blk..r * blk + p.len().min(blk)]
                .copy_from_slice(&p.as_slice()[..p.len().min(blk)]);
        }
        Ok(())
    })
}
