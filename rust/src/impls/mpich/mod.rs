//! The MPICH-like implementation ABI.
//!
//! Handles are C `int`s (§3.3): two *kind* bits (invalid / builtin /
//! direct), four object-type bits, and a payload. Builtin datatype
//! handles encode the element size in bits 8..16 — the paper quotes the
//! real macro:
//!
//! ```c
//! #define MPIR_Datatype_get_basic_size(a) (((a)&0x0000ff00)>>8)
//! ```
//!
//! so `MPI_CHAR = 0x4c000101` (size 1, index 1), `MPI_DOUBLE ≈
//! 0x4c00080b` (size 8). Predefined constants are **compile-time
//! constants** (`pub const`), the status layout is the MPICH-ABI-
//! initiative one (count split across two leading ints), wildcard
//! integers use MPICH's venerable values (`MPI_ANY_SOURCE = -2`), and
//! error codes are "rich": class in the low bits, a set bit marking a
//! code ≠ class.

use once_cell::sync::Lazy;

use super::repr::{Backed, Repr};
use crate::api::{dt_to_abi_const, op_to_abi_const, Dt, OpName};
use crate::core::request::StatusCore;
use crate::core::{err, CommId, DtId, ErrhId, GroupId, InfoId, OpId, RC, ReqId, SessionId, WinId};

/// The public ABI type: `MpichAbi::send(...)` etc.
pub type MpichAbi = Backed<MpichRepr>;

// --- Handle bit layout -------------------------------------------------------

/// Kind field (bits 30..32): an invalid (null) handle.
pub const KIND_INVALID: i32 = 0x0000_0000;
/// Kind field: a builtin (predefined) object.
pub const KIND_BUILTIN: i32 = 0x4000_0000;
/// Kind field: a "direct" (runtime-allocated) object.
pub const KIND_DIRECT: i32 = -0x8000_0000; // 0x8000_0000 as i32

/// Object-type field (bits 26..30), MPICH's numbering: communicator.
pub const T_COMM: i32 = 0x1 << 26;
/// Object-type field: group.
pub const T_GROUP: i32 = 0x2 << 26;
/// Object-type field: datatype.
pub const T_DATATYPE: i32 = 0x3 << 26;
/// Object-type field: file.
pub const T_FILE: i32 = 0x4 << 26;
/// Object-type field: error handler.
pub const T_ERRHANDLER: i32 = 0x5 << 26;
/// Object-type field: reduction op.
pub const T_OP: i32 = 0x6 << 26;
/// Object-type field: info object.
pub const T_INFO: i32 = 0x7 << 26;
/// Object-type field: RMA window.
pub const T_WIN: i32 = 0x8 << 26;
/// Object-type field: MPI-4 session.
pub const T_SESSION: i32 = 0x9 << 26;
/// Object-type field: request.
pub const T_REQUEST: i32 = 0xB << 26;

const KIND_MASK: i32 = KIND_DIRECT | KIND_BUILTIN; // top two bits
const TYPE_MASK: i32 = 0xF << 26;
const PAYLOAD_MASK: i32 = (1 << 26) - 1;

/// Extract a handle's kind bits.
#[inline(always)]
pub fn kind_of(h: i32) -> i32 {
    h & KIND_MASK
}

/// Extract a handle's object-type bits.
#[inline(always)]
pub fn type_of(h: i32) -> i32 {
    h & TYPE_MASK
}

/// Extract a handle's payload (the engine object index).
#[inline(always)]
pub fn payload_of(h: i32) -> i32 {
    h & PAYLOAD_MASK
}

// --- Predefined constants (compile-time, like real MPICH) --------------------

/// MPICH's `MPI_COMM_NULL` (compile-time constant).
pub const MPI_COMM_NULL: i32 = KIND_INVALID | T_COMM; // 0x04000000
/// MPICH's `MPI_COMM_WORLD`.
pub const MPI_COMM_WORLD: i32 = KIND_BUILTIN | T_COMM; // 0x44000000
/// MPICH's `MPI_COMM_SELF`.
pub const MPI_COMM_SELF: i32 = KIND_BUILTIN | T_COMM | 1; // 0x44000001

/// MPICH's `MPI_GROUP_NULL`.
pub const MPI_GROUP_NULL: i32 = KIND_INVALID | T_GROUP;
/// MPICH's `MPI_GROUP_EMPTY`.
pub const MPI_GROUP_EMPTY: i32 = KIND_BUILTIN | T_GROUP;

/// MPICH's `MPI_DATATYPE_NULL`.
pub const MPI_DATATYPE_NULL: i32 = KIND_INVALID | T_DATATYPE; // 0x0c000000
/// MPICH's `MPI_REQUEST_NULL`.
pub const MPI_REQUEST_NULL: i32 = KIND_INVALID | T_REQUEST; // 0x2c000000
/// MPICH's `MPI_OP_NULL`.
pub const MPI_OP_NULL: i32 = KIND_INVALID | T_OP; // 0x18000000
/// MPICH's `MPI_ERRHANDLER_NULL`.
pub const MPI_ERRHANDLER_NULL: i32 = KIND_INVALID | T_ERRHANDLER;
/// MPICH's `MPI_INFO_NULL`.
pub const MPI_INFO_NULL: i32 = KIND_INVALID | T_INFO;

/// MPICH's `MPI_ERRORS_ARE_FATAL`.
pub const MPI_ERRORS_ARE_FATAL: i32 = KIND_BUILTIN | T_ERRHANDLER; // 0x54000000
/// MPICH's `MPI_ERRORS_RETURN`.
pub const MPI_ERRORS_RETURN: i32 = KIND_BUILTIN | T_ERRHANDLER | 1;
/// MPICH's `MPI_ERRORS_ABORT`.
pub const MPI_ERRORS_ABORT: i32 = KIND_BUILTIN | T_ERRHANDLER | 2;
/// MPICH's `MPI_INFO_ENV`.
pub const MPI_INFO_ENV: i32 = KIND_BUILTIN | T_INFO;
/// MPICH's `MPI_WIN_NULL` — the window handle is an `int` like every
/// other MPICH handle, with the `T_WIN` object-type bits.
pub const MPI_WIN_NULL: i32 = KIND_INVALID | T_WIN; // 0x20000000
/// MPICH's `MPI_SESSION_NULL` — sessions are `int` handles too, with
/// their own object-type bits.
pub const MPI_SESSION_NULL: i32 = KIND_INVALID | T_SESSION; // 0x24000000

/// MPICH's historical `MPI_LOCK_EXCLUSIVE` — nowhere near the standard
/// ABI's small integers, so translation layers must map it.
pub const MPI_LOCK_EXCLUSIVE: i32 = 234;
/// MPICH's historical `MPI_LOCK_SHARED`.
pub const MPI_LOCK_SHARED: i32 = 235;

/// `MPI_ANY_SOURCE` — MPICH's historical value, deliberately different
/// from the standard ABI's unique negatives.
pub const MPI_ANY_SOURCE: i32 = -2;
/// `MPI_ANY_TAG` (aliases `MPI_PROC_NULL` — the §5.4 ambiguity the
/// standard ABI eliminates).
pub const MPI_ANY_TAG: i32 = -1;
/// `MPI_PROC_NULL` in MPICH's numbering.
pub const MPI_PROC_NULL: i32 = -1;
/// `MPI_ROOT` in MPICH's numbering.
pub const MPI_ROOT: i32 = -3;
/// `MPI_UNDEFINED` in MPICH's numbering.
pub const MPI_UNDEFINED: i32 = -32766;
/// `MPI_COMM_TYPE_SHARED` in MPICH's numbering.
pub const MPI_COMM_TYPE_SHARED: i32 = 1;

/// `MPI_IN_PLACE` in MPICH is `(void *) -1`.
pub const fn in_place_ptr() -> *const u8 {
    usize::MAX as *const u8
}

/// Builtin datatype handle: size in bits 8..16, engine index in bits 0..8.
#[inline(always)]
pub const fn dt_handle(size: usize, index: usize) -> i32 {
    KIND_BUILTIN | T_DATATYPE | ((size as i32) << 8) | index as i32
}

/// The quoted MPICH macro.
#[inline(always)]
pub fn datatype_get_basic_size(h: i32) -> i32 {
    (h & 0x0000_ff00) >> 8
}

/// Builtin datatype handles, indexed by engine dt id (= position in
/// [`crate::abi::datatypes::PREDEFINED_DATATYPES`]).
pub static DT_HANDLES: Lazy<Vec<i32>> = Lazy::new(|| {
    crate::abi::datatypes::PREDEFINED_DATATYPES
        .iter()
        .enumerate()
        .map(|(i, &(_, abi))| {
            let size = crate::abi::datatypes::platform_size_of(abi).unwrap_or(0);
            if i == 0 {
                MPI_DATATYPE_NULL
            } else {
                dt_handle(size, i)
            }
        })
        .collect()
});

/// Classic `MPI_CHAR` handle (spot-checked against the paper).
pub fn mpi_char() -> i32 {
    handle_for(crate::abi::datatypes::MPI_CHAR)
}
/// Classic `MPI_INT` handle.
pub fn mpi_int() -> i32 {
    handle_for(crate::abi::datatypes::MPI_INT)
}
/// Classic `MPI_DOUBLE` handle.
pub fn mpi_double() -> i32 {
    handle_for(crate::abi::datatypes::MPI_DOUBLE)
}

fn handle_for(abi_dt: usize) -> i32 {
    let id = crate::core::datatype::builtin_id_of_abi(abi_dt).unwrap();
    DT_HANDLES[id.0 as usize]
}

/// Builtin op handle: engine op index in the payload. `MPI_SUM =
/// 0x58000001`, as in real MPICH.
#[inline(always)]
pub const fn op_handle(index: usize) -> i32 {
    KIND_BUILTIN | T_OP | index as i32
}

// --- Status: the MPICH-ABI-initiative layout (§3.2.1) -------------------------

/// The MPICH-ABI-initiative `MPI_Status` layout: the hidden count split
/// across two leading ints (with the cancelled flag in the top bit),
/// then the three public fields.
#[repr(C)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[allow(non_snake_case)]
pub struct MpichStatus {
    /// Low 32 bits of the received byte count.
    pub count_lo: i32,
    /// High count bits (bit 31 = cancelled flag).
    pub count_hi_and_cancelled: i32,
    /// Public `MPI_SOURCE` field.
    pub MPI_SOURCE: i32,
    /// Public `MPI_TAG` field.
    pub MPI_TAG: i32,
    /// Public `MPI_ERROR` field.
    pub MPI_ERROR: i32,
}

const _: () = assert!(core::mem::size_of::<MpichStatus>() == 20);

impl MpichStatus {
    /// Reassemble the 63-bit received byte count.
    pub fn count_bytes(&self) -> u64 {
        let hi = (self.count_hi_and_cancelled as u32 & 0x7FFF_FFFF) as u64;
        (hi << 32) | self.count_lo as u32 as u64
    }

    /// The `MPI_Test_cancelled` flag (top bit of the high count word).
    pub fn cancelled(&self) -> bool {
        (self.count_hi_and_cancelled as u32) & 0x8000_0000 != 0
    }
}

// --- Error codes: rich encoding, class in low 8 bits ---------------------------

/// Codes carry the class in the low byte; bit 14 marks "code beyond
/// class" so codes are visibly ≠ standard-ABI classes (forcing layers to
/// translate).
pub fn err_code(class: i32) -> i32 {
    if class == 0 {
        0
    } else {
        class | 0x4000
    }
}

/// Extract the canonical class from a rich MPICH error code.
pub fn err_class(code: i32) -> i32 {
    code & 0xFF
}

// --- The Repr ------------------------------------------------------------------

/// The MPICH-like representation backend (see the module docs).
pub struct MpichRepr;

impl Repr for MpichRepr {
    const NAME: &'static str = "mpich";

    type Comm = i32;
    type Datatype = i32;
    type Op = i32;
    type Request = i32;
    type Group = i32;
    type Errhandler = i32;
    type Info = i32;
    type Win = i32;
    type Session = i32;
    type Status = MpichStatus;

    fn c_comm_world() -> i32 {
        MPI_COMM_WORLD
    }
    fn c_comm_self() -> i32 {
        MPI_COMM_SELF
    }
    fn c_comm_null() -> i32 {
        MPI_COMM_NULL
    }
    fn c_request_null() -> i32 {
        MPI_REQUEST_NULL
    }
    fn c_errh_return() -> i32 {
        MPI_ERRORS_RETURN
    }
    fn c_errh_fatal() -> i32 {
        MPI_ERRORS_ARE_FATAL
    }
    fn c_info_null() -> i32 {
        MPI_INFO_NULL
    }
    fn c_win_null() -> i32 {
        MPI_WIN_NULL
    }
    fn c_session_null() -> i32 {
        MPI_SESSION_NULL
    }
    fn c_lock_exclusive() -> i32 {
        MPI_LOCK_EXCLUSIVE
    }
    fn c_lock_shared() -> i32 {
        MPI_LOCK_SHARED
    }

    fn c_datatype(d: Dt) -> i32 {
        handle_for(dt_to_abi_const(d))
    }

    fn c_op(o: OpName) -> i32 {
        let id = crate::core::op::builtin_id_of_abi(op_to_abi_const(o)).unwrap();
        op_handle(id.0 as usize)
    }

    fn c_any_source() -> i32 {
        MPI_ANY_SOURCE
    }
    fn c_any_tag() -> i32 {
        MPI_ANY_TAG
    }
    fn c_proc_null() -> i32 {
        MPI_PROC_NULL
    }
    fn c_undefined() -> i32 {
        MPI_UNDEFINED
    }
    fn c_comm_type_shared() -> i32 {
        MPI_COMM_TYPE_SHARED
    }
    fn c_in_place() -> *const u8 {
        in_place_ptr()
    }

    #[inline]
    fn comm_id(c: i32) -> RC<CommId> {
        match c {
            MPI_COMM_WORLD => Ok(crate::core::reserved::COMM_WORLD),
            MPI_COMM_SELF => Ok(crate::core::reserved::COMM_SELF),
            _ if kind_of(c) == KIND_DIRECT && type_of(c) == T_COMM => {
                Ok(CommId(payload_of(c) as u32))
            }
            _ => Err(err!(MPI_ERR_COMM)),
        }
    }

    #[inline]
    fn comm_h(id: CommId) -> i32 {
        match id {
            crate::core::reserved::COMM_WORLD => MPI_COMM_WORLD,
            crate::core::reserved::COMM_SELF => MPI_COMM_SELF,
            CommId(n) => KIND_DIRECT | T_COMM | n as i32,
        }
    }

    #[inline]
    fn dt_id(d: i32) -> RC<DtId> {
        match kind_of(d) {
            KIND_BUILTIN if type_of(d) == T_DATATYPE => Ok(DtId((d & 0xFF) as u32)),
            KIND_DIRECT if type_of(d) == T_DATATYPE => Ok(DtId(payload_of(d) as u32)),
            _ => Err(err!(MPI_ERR_TYPE)),
        }
    }

    #[inline]
    fn dt_h(id: DtId) -> i32 {
        if (id.0 as usize) < DT_HANDLES.len() {
            DT_HANDLES[id.0 as usize]
        } else {
            KIND_DIRECT | T_DATATYPE | id.0 as i32
        }
    }

    #[inline]
    fn op_id(o: i32) -> RC<OpId> {
        match kind_of(o) {
            KIND_BUILTIN if type_of(o) == T_OP => Ok(OpId(payload_of(o) as u32)),
            KIND_DIRECT if type_of(o) == T_OP => Ok(OpId(payload_of(o) as u32)),
            _ => Err(err!(MPI_ERR_OP)),
        }
    }

    #[inline]
    fn op_h(id: OpId) -> i32 {
        if id.0 < crate::core::reserved::NUM_BUILTIN_OPS {
            op_handle(id.0 as usize)
        } else {
            KIND_DIRECT | T_OP | id.0 as i32
        }
    }

    #[inline]
    fn req_id(r: i32) -> RC<ReqId> {
        if kind_of(r) == KIND_DIRECT && type_of(r) == T_REQUEST {
            Ok(ReqId(payload_of(r) as u32))
        } else {
            Err(err!(MPI_ERR_REQUEST))
        }
    }

    #[inline]
    fn req_h(id: ReqId) -> i32 {
        KIND_DIRECT | T_REQUEST | id.0 as i32
    }

    #[inline]
    fn group_id(g: i32) -> RC<GroupId> {
        match kind_of(g) {
            KIND_BUILTIN if type_of(g) == T_GROUP => Ok(GroupId(payload_of(g) as u32)),
            KIND_DIRECT if type_of(g) == T_GROUP => Ok(GroupId(payload_of(g) as u32)),
            _ => Err(err!(MPI_ERR_GROUP)),
        }
    }

    #[inline]
    fn group_h(id: GroupId) -> i32 {
        if id.0 <= 2 {
            KIND_BUILTIN | T_GROUP | id.0 as i32
        } else {
            KIND_DIRECT | T_GROUP | id.0 as i32
        }
    }

    #[inline]
    fn errh_id(e: i32) -> RC<ErrhId> {
        match kind_of(e) {
            KIND_BUILTIN if type_of(e) == T_ERRHANDLER => Ok(ErrhId(payload_of(e) as u32)),
            KIND_DIRECT if type_of(e) == T_ERRHANDLER => Ok(ErrhId(payload_of(e) as u32)),
            _ => Err(err!(MPI_ERR_ARG)),
        }
    }

    #[inline]
    fn errh_h(id: ErrhId) -> i32 {
        if id.0 <= 2 {
            KIND_BUILTIN | T_ERRHANDLER | id.0 as i32
        } else {
            KIND_DIRECT | T_ERRHANDLER | id.0 as i32
        }
    }

    #[inline]
    fn info_id(i: i32) -> RC<InfoId> {
        match kind_of(i) {
            KIND_BUILTIN if type_of(i) == T_INFO => Ok(InfoId(payload_of(i) as u32)),
            KIND_DIRECT if type_of(i) == T_INFO => Ok(InfoId(payload_of(i) as u32)),
            _ => Err(err!(MPI_ERR_INFO)),
        }
    }

    #[inline]
    fn info_h(id: InfoId) -> i32 {
        if id.0 == 0 {
            MPI_INFO_ENV
        } else {
            KIND_DIRECT | T_INFO | id.0 as i32
        }
    }

    #[inline]
    fn win_id(w: i32) -> RC<WinId> {
        if kind_of(w) == KIND_DIRECT && type_of(w) == T_WIN {
            Ok(WinId(payload_of(w) as u32))
        } else {
            Err(err!(MPI_ERR_WIN))
        }
    }

    #[inline]
    fn win_h(id: WinId) -> i32 {
        KIND_DIRECT | T_WIN | id.0 as i32
    }

    #[inline]
    fn session_id(s: i32) -> RC<SessionId> {
        if kind_of(s) == KIND_DIRECT && type_of(s) == T_SESSION {
            Ok(SessionId(payload_of(s) as u32))
        } else {
            Err(err!(MPI_ERR_SESSION))
        }
    }

    #[inline]
    fn session_h(id: SessionId) -> i32 {
        KIND_DIRECT | T_SESSION | id.0 as i32
    }

    fn status_empty() -> MpichStatus {
        MpichStatus {
            count_lo: 0,
            count_hi_and_cancelled: 0,
            MPI_SOURCE: MPI_PROC_NULL,
            MPI_TAG: MPI_ANY_TAG,
            MPI_ERROR: 0,
        }
    }

    fn status_from_core(s: &StatusCore) -> MpichStatus {
        let hi = ((s.count_bytes >> 32) & 0x7FFF_FFFF) as u32
            | if s.cancelled { 0x8000_0000 } else { 0 };
        MpichStatus {
            count_lo: (s.count_bytes & 0xFFFF_FFFF) as u32 as i32,
            count_hi_and_cancelled: hi as i32,
            MPI_SOURCE: s.source,
            MPI_TAG: s.tag,
            MPI_ERROR: s.error,
        }
    }

    fn status_source(s: &MpichStatus) -> i32 {
        s.MPI_SOURCE
    }
    fn status_tag(s: &MpichStatus) -> i32 {
        s.MPI_TAG
    }
    fn status_error(s: &MpichStatus) -> i32 {
        s.MPI_ERROR
    }
    fn status_cancelled(s: &MpichStatus) -> bool {
        s.cancelled()
    }
    fn status_count_bytes(s: &MpichStatus) -> u64 {
        s.count_bytes()
    }

    fn err_from_class(class: i32) -> i32 {
        err_code(class)
    }
    fn class_of_err(code: i32) -> i32 {
        err_class(code)
    }

    /// MPICH's mechanism: decode the size from the handle bits — no
    /// memory access for builtins.
    #[inline(always)]
    fn type_size_fast(d: i32) -> Option<i32> {
        if kind_of(d) == KIND_BUILTIN && type_of(d) == T_DATATYPE {
            Some(datatype_get_basic_size(d))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_match_real_mpich_values() {
        assert_eq!(MPI_COMM_WORLD, 0x44000000);
        assert_eq!(MPI_COMM_SELF, 0x44000001);
        assert_eq!(MPI_COMM_NULL, 0x04000000);
        assert_eq!(MPI_REQUEST_NULL, 0x2c000000u32 as i32);
        assert_eq!(MPI_ERRORS_ARE_FATAL, 0x54000000);
        assert_eq!(op_handle(1), 0x58000001, "MPI_SUM");
    }

    #[test]
    fn datatype_handles_encode_size() {
        // Paper: MPI_CHAR = 0x4c000101-style (size byte = 1).
        let c = mpi_char();
        assert_eq!(kind_of(c), KIND_BUILTIN);
        assert_eq!(type_of(c), T_DATATYPE);
        assert_eq!(datatype_get_basic_size(c), 1);
        assert_eq!(datatype_get_basic_size(mpi_int()), 4);
        assert_eq!(datatype_get_basic_size(mpi_double()), 8);
    }

    #[test]
    fn status_layout_is_the_abi_initiative_one() {
        // count fields lead, then SOURCE/TAG/ERROR.
        assert_eq!(core::mem::size_of::<MpichStatus>(), 20);
        let s = MpichStatus {
            count_lo: 1,
            count_hi_and_cancelled: 2,
            MPI_SOURCE: 3,
            MPI_TAG: 4,
            MPI_ERROR: 5,
        };
        let base = &s as *const _ as usize;
        assert_eq!(&s.MPI_SOURCE as *const _ as usize - base, 8);
    }

    #[test]
    fn error_codes_are_not_classes() {
        let code = err_code(crate::abi::errors::MPI_ERR_TRUNCATE);
        assert_ne!(code, crate::abi::errors::MPI_ERR_TRUNCATE);
        assert_eq!(err_class(code), crate::abi::errors::MPI_ERR_TRUNCATE);
        assert_eq!(err_code(0), 0, "success stays 0 in every ABI");
    }

    #[test]
    fn wildcards_differ_from_standard_abi() {
        assert_ne!(MPI_ANY_SOURCE, crate::abi::constants::MPI_ANY_SOURCE);
        assert_ne!(MPI_ANY_TAG, crate::abi::constants::MPI_ANY_TAG);
        // MPICH's PROC_NULL == ANY_TAG == -1: the aliasing the standard
        // ABI's unique negatives were designed to eliminate (§5.4).
        assert_eq!(MPI_PROC_NULL, MPI_ANY_TAG);
    }
}
