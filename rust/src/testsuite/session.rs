//! MPI-4 Sessions tests: init/finalize ordering (world and sessions
//! coexist; finalize order is free), the process-set queries and their
//! error cases, `MPI_Group_from_session_pset`, and
//! `MPI_Comm_create_from_group` with tag-string disambiguation.
//!
//! These run *inside* a world-model job (the suite harness calls
//! `MPI_Init`), which is exactly the coexistence MPI-4 §11 requires;
//! the sessions-*only* path (no `MPI_Init` at all) is covered by
//! `tests/sessions.rs` and the sessions-only halo acceptance test.

use super::util::*;
use super::TestFn;
use crate::api::{Dt, MpiAbi, OpName};
use crate::core::session::{PSET_SELF, PSET_WORLD};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("session.init_finalize", init_finalize::<A>),
        ("session.finalize_order_is_free", finalize_order_is_free::<A>),
        ("session.world_coexistence", world_coexistence::<A>),
        ("session.pset_enumeration", pset_enumeration::<A>),
        ("session.pset_info", pset_info::<A>),
        ("session.unknown_pset_errors", unknown_pset_errors::<A>),
        ("session.group_from_pset", group_from_pset::<A>),
        ("session.comm_from_world_pset", comm_from_world_pset::<A>),
        ("session.comm_from_self_pset", comm_from_self_pset::<A>),
        ("session.tag_disambiguation", tag_disambiguation::<A>),
        ("session.double_finalize_errors", double_finalize_errors::<A>),
        ("session.null_session_errors", null_session_errors::<A>),
    ]
}

fn world_geometry<A: MpiAbi>() -> (i32, i32) {
    let (mut size, mut rank) = (0, 0);
    A::comm_size(A::comm_world(), &mut size);
    A::comm_rank(A::comm_world(), &mut rank);
    (size, rank)
}

/// Open a session, run `f`, finalize. Saves each test the boilerplate.
fn with_session<A: MpiAbi, F: FnOnce(A::Session) -> Result<(), String>>(
    f: F,
) -> Result<(), String> {
    let mut s = A::session_null();
    check_rc!(A::session_init(A::info_null(), A::errhandler_return(), &mut s), "session_init");
    check!(s != A::session_null(), "session_init yields a non-null handle");
    f(s)?;
    let mut s2 = s;
    check_rc!(A::session_finalize(&mut s2), "session_finalize");
    check!(s2 == A::session_null(), "session_finalize nulls the handle");
    Ok(())
}

fn init_finalize<A: MpiAbi>(_r: usize) -> Result<(), String> {
    with_session::<A, _>(|_s| Ok(()))
}

fn finalize_order_is_free<A: MpiAbi>(_r: usize) -> Result<(), String> {
    // Two sessions, finalized in creation order (s1 before s2) — the
    // refcount, not a stack, governs lifetime.
    let mut s1 = A::session_null();
    let mut s2 = A::session_null();
    check_rc!(A::session_init(A::info_null(), A::errhandler_return(), &mut s1), "init s1");
    check_rc!(A::session_init(A::info_null(), A::errhandler_return(), &mut s2), "init s2");
    check!(s1 != s2, "distinct sessions get distinct handles");
    check_rc!(A::session_finalize(&mut s1), "finalize s1 first");
    // s2 is still fully usable after s1 is gone.
    let mut n = 0;
    check_rc!(A::session_get_num_psets(s2, &mut n), "num_psets on surviving session");
    check!(n >= 2, "psets visible after sibling finalize");
    check_rc!(A::session_finalize(&mut s2), "finalize s2");
    Ok(())
}

fn world_coexistence<A: MpiAbi>(_r: usize) -> Result<(), String> {
    // The suite runs under the world model; a session on top must not
    // perturb MPI_Initialized / MPI_Finalized.
    with_session::<A, _>(|_s| {
        check!(A::initialized(), "initialized with world + session active");
        check!(!A::finalized(), "not finalized while epochs are active");
        Ok(())
    })?;
    check!(A::initialized(), "still initialized after session close");
    check!(!A::finalized(), "world epoch still open");
    Ok(())
}

fn pset_enumeration<A: MpiAbi>(_r: usize) -> Result<(), String> {
    with_session::<A, _>(|s| {
        let mut n = 0;
        check_rc!(A::session_get_num_psets(s, &mut n), "get_num_psets");
        check!(n >= 2, "at least mpi://WORLD and mpi://SELF ({n})");
        let mut names = Vec::new();
        for i in 0..n {
            let mut name = String::new();
            check_rc!(A::session_get_nth_pset(s, i, &mut name), "get_nth_pset");
            names.push(name);
        }
        check!(names[0] == PSET_WORLD, "pset 0 is {PSET_WORLD} (got {:?})", names[0]);
        check!(names[1] == PSET_SELF, "pset 1 is {PSET_SELF} (got {:?})", names[1]);
        // Out-of-range index errors.
        let mut name = String::new();
        check!(A::session_get_nth_pset(s, n, &mut name) != 0, "index {n} out of range");
        Ok(())
    })
}

fn pset_info<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (size, _) = world_geometry::<A>();
    with_session::<A, _>(|s| {
        for (pset, want) in [(PSET_WORLD, size), (PSET_SELF, 1)] {
            let mut info = A::info_null();
            check_rc!(A::session_get_pset_info(s, pset, &mut info), "get_pset_info");
            let mut v = String::new();
            let mut flag = false;
            check_rc!(A::info_get(info, "mpi_size", &mut v, &mut flag), "info_get");
            check!(flag, "{pset} info has mpi_size");
            check!(v == want.to_string(), "{pset} mpi_size {v:?}, want {want}");
            check_rc!(A::info_free(&mut info), "info_free");
        }
        Ok(())
    })
}

fn unknown_pset_errors<A: MpiAbi>(_r: usize) -> Result<(), String> {
    with_session::<A, _>(|s| {
        let mut info = A::info_null();
        check!(
            A::session_get_pset_info(s, "mpi://NO_SUCH_SET", &mut info) != 0,
            "pset_info on unknown set errors"
        );
        let mut g = unsafe { std::mem::zeroed::<A::Group>() };
        check!(
            A::group_from_session_pset(s, "mpi://NO_SUCH_SET", &mut g) != 0,
            "group_from_session_pset on unknown set errors"
        );
        Ok(())
    })
}

fn group_from_pset<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (size, rank) = world_geometry::<A>();
    with_session::<A, _>(|s| {
        let mut g = unsafe { std::mem::zeroed::<A::Group>() };
        check_rc!(A::group_from_session_pset(s, PSET_WORLD, &mut g), "group from WORLD");
        let (mut gs, mut gr) = (0, -1);
        check_rc!(A::group_size(g, &mut gs), "group_size");
        check_rc!(A::group_rank(g, &mut gr), "group_rank");
        check!(gs == size, "WORLD group spans the job ({gs} vs {size})");
        check!(gr == rank, "WORLD group preserves rank order ({gr} vs {rank})");
        check_rc!(A::group_free(&mut g), "free WORLD group");

        // Pset names are URIs: case-insensitive.
        let mut g2 = unsafe { std::mem::zeroed::<A::Group>() };
        check_rc!(A::group_from_session_pset(s, "MPI://world", &mut g2), "case-insensitive");
        check_rc!(A::group_free(&mut g2), "free");

        let mut gself = unsafe { std::mem::zeroed::<A::Group>() };
        check_rc!(A::group_from_session_pset(s, PSET_SELF, &mut gself), "group from SELF");
        let mut ss = 0;
        check_rc!(A::group_size(gself, &mut ss), "self size");
        check!(ss == 1, "SELF group is a singleton");
        check_rc!(A::group_free(&mut gself), "free SELF group");
        Ok(())
    })
}

fn comm_from_world_pset<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (size, rank) = world_geometry::<A>();
    with_session::<A, _>(|s| {
        let mut g = unsafe { std::mem::zeroed::<A::Group>() };
        check_rc!(A::group_from_session_pset(s, PSET_WORLD, &mut g), "group");
        let mut comm = A::comm_null();
        check_rc!(
            A::comm_create_from_group(g, "suite://world-pset", A::info_null(),
                A::errhandler_return(), &mut comm),
            "comm_create_from_group"
        );
        check_rc!(A::group_free(&mut g), "group_free");
        let (mut cs, mut cr) = (0, -1);
        check_rc!(A::comm_size(comm, &mut cs), "comm_size");
        check_rc!(A::comm_rank(comm, &mut cr), "comm_rank");
        check!(cs == size && cr == rank, "derived comm mirrors the world ({cs}/{cr})");
        // The derived comm carries real traffic: allreduce of 1 = size.
        let one = 1i32;
        let mut sum = 0i32;
        check_rc!(
            A::allreduce(ptr(&one), ptr_mut(&mut sum), 1, A::datatype(Dt::Int),
                A::op(OpName::Sum), comm),
            "allreduce on derived comm"
        );
        check!(sum == size, "allreduce over session comm ({sum} vs {size})");
        check_rc!(A::comm_free(&mut comm), "comm_free");
        Ok(())
    })
}

fn comm_from_self_pset<A: MpiAbi>(_r: usize) -> Result<(), String> {
    with_session::<A, _>(|s| {
        let mut g = unsafe { std::mem::zeroed::<A::Group>() };
        check_rc!(A::group_from_session_pset(s, PSET_SELF, &mut g), "group");
        let mut comm = A::comm_null();
        check_rc!(
            A::comm_create_from_group(g, "suite://self-pset", A::info_null(),
                A::errhandler_return(), &mut comm),
            "comm_create_from_group over a singleton group"
        );
        check_rc!(A::group_free(&mut g), "group_free");
        let mut cs = 0;
        check_rc!(A::comm_size(comm, &mut cs), "comm_size");
        check!(cs == 1, "SELF-derived comm is a singleton");
        check_rc!(A::comm_free(&mut comm), "comm_free");
        Ok(())
    })
}

/// Two communicators derived concurrently from the same (world) group:
/// rank 0 creates them in order (a, b), every other rank in order
/// (b, a). Only the tag strings keep the two context-plane agreements
/// apart — this is the MPI-4 §11.6 disambiguation rule, exercised.
fn tag_disambiguation<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (size, rank) = world_geometry::<A>();
    with_session::<A, _>(|s| {
        let mut g = unsafe { std::mem::zeroed::<A::Group>() };
        check_rc!(A::group_from_session_pset(s, PSET_WORLD, &mut g), "group");
        let make = |tag: &str| -> Result<A::Comm, String> {
            let mut c = A::comm_null();
            let rc = A::comm_create_from_group(g, tag, A::info_null(), A::errhandler_return(),
                &mut c);
            if rc != 0 {
                return Err(format!("comm_create_from_group({tag}) rc {rc}"));
            }
            Ok(c)
        };
        let (mut ca, mut cb) = if rank == 0 {
            let a = make("suite://disamb/a")?;
            let b = make("suite://disamb/b")?;
            (a, b)
        } else {
            let b = make("suite://disamb/b")?;
            let a = make("suite://disamb/a")?;
            (a, b)
        };
        check_rc!(A::group_free(&mut g), "group_free");
        // Every rank agreed on which comm is which: reductions with
        // distinct payloads land on the right plane.
        for (comm, val) in [(ca, 1i32), (cb, 1000i32)] {
            let mut sum = 0i32;
            check_rc!(
                A::allreduce(ptr(&val), ptr_mut(&mut sum), 1, A::datatype(Dt::Int),
                    A::op(OpName::Sum), comm),
                "allreduce"
            );
            check!(sum == val * size, "disambiguated comm sums {sum} (want {})", val * size);
        }
        // Same membership, different contexts: congruent, not identical.
        let mut cmp = -1;
        check_rc!(A::comm_compare(ca, cb, &mut cmp), "comm_compare");
        check!(
            cmp == crate::abi::constants::MPI_CONGRUENT,
            "two derived comms are congruent (got {cmp})"
        );
        check_rc!(A::comm_free(&mut ca), "free a");
        check_rc!(A::comm_free(&mut cb), "free b");
        Ok(())
    })
}

fn double_finalize_errors<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut s = A::session_null();
    check_rc!(A::session_init(A::info_null(), A::errhandler_return(), &mut s), "init");
    check_rc!(A::session_finalize(&mut s), "first finalize");
    // The handle is now MPI_SESSION_NULL; finalizing again must error.
    check!(A::session_finalize(&mut s) != 0, "double finalize errors");
    Ok(())
}

fn null_session_errors<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut n = 0;
    check!(
        A::session_get_num_psets(A::session_null(), &mut n) != 0,
        "queries on MPI_SESSION_NULL error"
    );
    let mut name = String::new();
    check!(
        A::session_get_nth_pset(A::session_null(), 0, &mut name) != 0,
        "nth_pset on MPI_SESSION_NULL errors"
    );
    Ok(())
}
