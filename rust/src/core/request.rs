//! Requests, the request **lifecycle state machine**, and the progress
//! engine.
//!
//! Every nonblocking operation creates a request; blocking operations are
//! request + wait; persistent operations (`MPI_Send_init`,
//! `MPI_Recv_init`, the MPI-4 `*_init` collectives) create a request
//! *once* and re-arm it with `MPI_Start`. Progress is made inside
//! test/wait/recv loops (polling the fabric, matching posted receives
//! against arrivals, acking synchronous sends) — the single-threaded
//! progress model of most MPI implementations.
//!
//! # The lifecycle
//!
//! ```text
//!                    nonblocking path                persistent path
//!                    ----------------                ---------------
//!   isend/irecv ──► Active                *_init ──► Inactive ◄────────┐
//!                     │ op finishes                    │ MPI_Start     │
//!                     ▼                                ▼               │
//!                  Complete(status)                  Active            │
//!                     │ wait/test                      │ op finishes   │
//!                     ▼                                ▼               │
//!                  (freed)                           Complete(status)  │
//!                                                      │ wait/test ────┘
//!                                                      (request survives;
//!                                                       MPI_Request_free
//!                                                       only when Inactive)
//! ```
//!
//! The same three states drive every request kind; what differs is the
//! *re-arm recipe* ([`PersistSpec`]) a persistent request carries.
//! Schedule-backed (collective) requests keep their [`Schedule`] inside
//! [`ReqKind::Sched`] across restarts — `MPI_Start` resets and re-runs
//! it instead of rebuilding (see [`crate::core::collectives::sched`]).
//!
//! [`Schedule`]: crate::core::collectives::sched::Schedule

use super::obs::{trace, TraceKind};
use super::transport::{Envelope, MsgKind, Payload};
use super::world::{with_ctx, RankCtx};
use super::{err, DtId, ReqId, RC};
use crate::abi::constants::{MPI_ANY_TAG, MPI_PROC_NULL};

/// Clamp a `u64` trace payload into the event record's `u32` word.
#[inline]
fn clamp32(v: u64) -> u32 {
    v.min(u32::MAX as u64) as u32
}

/// Trace encoding of a receive's tag pattern (`MPI_ANY_TAG` → max).
#[inline]
fn trace_tag(tag: i32) -> u32 {
    if tag == MPI_ANY_TAG {
        u32::MAX
    } else {
        tag as u32
    }
}

/// Rendezvous chunk size in packed bytes: each [`MsgKind::RndvData`]
/// envelope carries at most this much payload, so peak buffering for a
/// transfer is `O(chunk × window)`, never `O(message)`.
pub const RNDV_CHUNK: usize = 64 * 1024;

/// Cumulative credit window: the receiver lets the sender run at most
/// this many bytes ahead of what it has consumed.
pub const RNDV_WINDOW_BYTES: u64 = 4 * RNDV_CHUNK as u64;

/// Re-grant hysteresis: a fresh CTS goes out once remaining credit falls
/// below this (half the window), keeping the pipe full without a CTS per
/// chunk.
const RNDV_REGRANT_BYTES: u64 = 2 * RNDV_CHUNK as u64;

/// Sender side of one rendezvous stream, keyed by stream id in
/// [`crate::core::world::RankState::rndv_sends`]. Created when a send
/// exceeds the threshold (RTS goes out); chunks flow once CTS credit
/// arrives; the entry leaves the map when the last chunk is enqueued —
/// that departure *is* send completion.
pub struct RndvSend {
    /// Destination world rank.
    pub dst: usize,
    /// Context plane of the send.
    pub context: u32,
    /// Message tag.
    pub tag: i32,
    /// User buffer address (chunks are packed straight from it).
    pub buf: usize,
    /// Element count.
    pub count: usize,
    /// Element datatype.
    pub dt: DtId,
    /// Full packed size in bytes.
    pub total: u64,
    /// Cumulative bytes already enqueued to the fabric.
    pub sent: u64,
    /// Cumulative byte credit granted by the receiver (0 until CTS).
    pub credit: u64,
    /// Fallback for the rare plan-less (deeply recursive) type: the
    /// whole message packed once up front, chunks sliced from it. Every
    /// plan-carrying type streams windowed from the user buffer instead.
    pub packed: Option<Vec<u8>>,
}

/// Receiver side of one rendezvous stream, keyed by
/// `(sender world rank, stream id)` in
/// [`crate::core::world::RankState::rndv_recvs`]. Created when an RTS
/// matches a posted receive (or a blocking recv takes it unexpected);
/// chunks scatter straight into the user buffer as they land.
pub struct RndvRecv {
    /// The receive request the stream completes — `None` for the
    /// blocking-recv inline path, which polls [`take_rndv_status`].
    pub rid: Option<ReqId>,
    /// Destination user buffer address.
    pub buf: usize,
    /// Element count posted.
    pub count: usize,
    /// Element datatype posted.
    pub dt: DtId,
    /// Posted buffer capacity in packed bytes (beyond it = truncation).
    pub cap: u64,
    /// Full packed size announced by the RTS.
    pub total: u64,
    /// Cumulative stream bytes consumed.
    pub received: u64,
    /// Cumulative credit granted so far.
    pub granted: u64,
    /// Message tag (for the final status and CTS routing).
    pub tag: i32,
    /// Context plane.
    pub context: u32,
    /// Fallback staging for plan-less types: the stream accumulates
    /// here and unpacks once at completion. Plan-carrying types scatter
    /// each chunk directly and never allocate this.
    pub staging: Option<Vec<u8>>,
    /// Completion status, set when the stream finishes — only used by
    /// the inline (`rid: None`) path.
    pub status: Option<StatusCore>,
}

/// Implementation-independent status record. Each ABI converts this to its
/// own status layout — the translation the paper's §3.2 catalogues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StatusCore {
    /// World rank of the message source (or `MPI_PROC_NULL`).
    pub source: i32,
    /// Message tag.
    pub tag: i32,
    /// Canonical (standard-ABI) error class.
    pub error: i32,
    /// Received payload size in packed bytes.
    pub count_bytes: u64,
    /// `MPI_Test_cancelled` flag.
    pub cancelled: bool,
}

impl StatusCore {
    /// Status of a successfully matched receive.
    pub fn success(source: i32, tag: i32, count_bytes: u64) -> StatusCore {
        StatusCore { source, tag, error: 0, count_bytes, cancelled: false }
    }

    /// Status for a send completion or PROC_NULL op.
    pub fn empty() -> StatusCore {
        StatusCore {
            source: MPI_PROC_NULL,
            tag: crate::abi::constants::MPI_ANY_TAG,
            error: 0,
            count_bytes: 0,
            cancelled: false,
        }
    }
}

/// What a request is waiting for.
pub enum ReqKind {
    /// Eager send: complete at creation (buffer copied).
    Send,
    /// Synchronous send: complete when the ack for `sync_id` arrives.
    Ssend {
        /// Ack id the matching receive will echo back.
        sync_id: u64,
        /// Destination world rank — kept so an unacked Ssend to a peer
        /// that dies completes with `MPI_ERR_PROC_FAILED` instead of
        /// waiting forever for an ack that cannot come.
        dst: usize,
    },
    /// Rendezvous send (standard or synchronous — CTS implies the match,
    /// so streaming out fully satisfies both): complete when stream
    /// `rndv` leaves [`crate::core::world::RankState::rndv_sends`].
    RndvSend {
        /// This rank's stream id.
        rndv: u64,
    },
    /// Posted receive.
    Recv {
        /// Destination buffer address.
        buf: usize,
        /// Element count.
        count: usize,
        /// Element datatype.
        dt: DtId,
        /// Matching source (world rank or `MPI_ANY_SOURCE`).
        src: i32,
        /// Matching tag (or `MPI_ANY_TAG`).
        tag: i32,
        /// Matching context plane.
        context: u32,
    },
    /// Nonblocking or persistent collective: a schedule advanced by the
    /// progress engine (see [`crate::core::collectives::sched`]).
    Sched(Box<crate::core::collectives::sched::Schedule>),
}

/// Lifecycle state of a request — see the module docs for the diagram.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReqState {
    /// Persistent request between starts (or before the first start).
    /// wait/test on an inactive request return immediately with an empty
    /// status (MPI 3.0 §3.7.3).
    Inactive,
    /// Operation in flight.
    Active,
    /// Operation finished; status not yet collected by wait/test.
    Complete(StatusCore),
}

/// The re-arm recipe of a persistent request: everything `MPI_Start`
/// needs to launch the operation again. Arguments were validated and
/// comm-resolved once, at `*_init` time — restarts skip straight to the
/// data path (the point of persistence).
#[derive(Clone, Copy, Debug)]
pub enum PersistSpec {
    /// `MPI_Send_init` / `MPI_Ssend_init`: each start re-packs the user
    /// buffer (picking up updated contents) and enqueues one envelope.
    Send {
        /// Source buffer address (re-read at every start).
        buf: usize,
        /// Element count.
        count: usize,
        /// Element datatype.
        dt: DtId,
        /// Destination world rank; `None` = `MPI_PROC_NULL` (each start
        /// completes immediately).
        dest_world: Option<usize>,
        /// Message tag.
        tag: i32,
        /// Pt2pt context plane of the communicator.
        context: u32,
        /// Synchronous mode (`MPI_Ssend_init`): active until acked.
        sync: bool,
    },
    /// `MPI_Recv_init`: each start re-posts the receive.
    Recv {
        /// Destination buffer address.
        buf: usize,
        /// Element count.
        count: usize,
        /// Element datatype.
        dt: DtId,
        /// Matching source: world rank, `MPI_ANY_SOURCE`, or
        /// `MPI_PROC_NULL` (start completes immediately).
        src: i32,
        /// Matching tag.
        tag: i32,
        /// Pt2pt context plane.
        context: u32,
    },
    /// Persistent collective: the [`Schedule`] living in this request's
    /// [`ReqKind::Sched`] is reset and re-armed by each start — reused,
    /// never rebuilt.
    ///
    /// [`Schedule`]: crate::core::collectives::sched::Schedule
    Coll,
}

/// One request-table entry: current kind, lifecycle state, and (for
/// persistent requests) the re-arm recipe.
pub struct RequestObj {
    /// What the request is currently doing (or armed to do).
    pub kind: ReqKind,
    /// Lifecycle state.
    pub state: ReqState,
    /// `Some` marks a persistent request; holds what `MPI_Start` re-arms.
    pub persist: Option<PersistSpec>,
}

/// Create a (nonpersistent) request in the table.
pub(crate) fn new_request(ctx: &RankCtx, kind: ReqKind, state: ReqState) -> ReqId {
    ReqId(ctx.tables.borrow_mut().reqs.insert(RequestObj { kind, state, persist: None }))
}

/// Create a persistent request in the table, born Inactive.
pub(crate) fn new_persistent(ctx: &RankCtx, kind: ReqKind, spec: PersistSpec) -> ReqId {
    ReqId(ctx.tables.borrow_mut().reqs.insert(RequestObj {
        kind,
        state: ReqState::Inactive,
        persist: Some(spec),
    }))
}

/// Post a receive request. The matching index either completes it on
/// the spot (a matching message already arrived) or files it for the
/// next arrival — there is no per-tick rescan (see
/// [`crate::core::match_index`]).
pub(crate) fn post_recv(
    ctx: &RankCtx,
    buf: usize,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    context: u32,
) -> ReqId {
    let id = new_request(ctx, ReqKind::Recv { buf, count, dt, src, tag, context }, ReqState::Active);
    trace(ctx, TraceKind::Post, context, trace_tag(tag));
    let hit = ctx.state.borrow_mut().match_index.post(id, context, src, tag);
    if let Some(env) = hit {
        deliver(ctx, id, env);
    }
    id
}

/// Re-post an existing (persistent) receive request: set its armed kind,
/// mark Active, and hand it to the matching index.
pub(crate) fn repost_recv(
    ctx: &RankCtx,
    rid: ReqId,
    buf: usize,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    context: u32,
) {
    {
        let mut t = ctx.tables.borrow_mut();
        if let Some(req) = t.reqs.get_mut(rid.0) {
            req.kind = ReqKind::Recv { buf, count, dt, src, tag, context };
            req.state = ReqState::Active;
        }
    }
    trace(ctx, TraceKind::Post, context, trace_tag(tag));
    let hit = ctx.state.borrow_mut().match_index.post(rid, context, src, tag);
    if let Some(env) = hit {
        deliver(ctx, rid, env);
    }
}

/// One progress cycle: flush deferred sends, drain the fabric (matching
/// every arrival as it lands), service one-sided traffic, then advance
/// every in-flight collective schedule.
pub(crate) fn progress(ctx: &RankCtx) {
    if let Some(code) = ctx.world.aborted() {
        std::panic::panic_any(super::world::AbortUnwind(code));
    }
    // Deterministic death injection: an armed victim counts progress
    // cycles and dies once its threshold passes. Non-victims pay one
    // Cell read.
    if let Some(kill_at) = ctx.kill_at.get() {
        let t = ctx.ticks.get() + 1;
        ctx.ticks.set(t);
        if t > kill_at {
            die(ctx);
        }
    }
    flush_pending_sends(ctx);
    drain_fabric(ctx);
    if ctx.world.any_dead() {
        fail_rndv_from_dead(ctx);
    }
    pump_rndv_sends(ctx);
    super::rma::progress_rma(ctx);
    super::collectives::sched::progress_scheds(ctx);
}

/// The injected death: mark this rank dead, drain (and discard) whatever
/// is already in its inbound fabric — a dead process consumes nothing
/// more, and the drain keeps senders' rings from wedging on a full ring
/// — then unwind the rank thread *without* aborting the job. Survivors
/// observe the death as `MPI_ERR_PROC_FAILED`.
fn die(ctx: &RankCtx) -> ! {
    ctx.world.mark_dead(ctx.rank);
    let mut inbox = std::mem::take(&mut ctx.state.borrow_mut().inbox);
    ctx.world.fabric.poll_into(ctx.rank, &mut inbox);
    inbox.clear();
    std::panic::panic_any(super::world::KilledUnwind);
}

/// Fail every in-flight rendezvous *receive* stream whose sender has
/// died: the stream can never finish, so its request (or inline status)
/// completes with `MPI_ERR_PROC_FAILED` instead of hanging. (Outbound
/// streams to a dead destination fail at their completion checks —
/// [`finish_if_done`] and the blocking-send spin.)
fn fail_rndv_from_dead(ctx: &RankCtx) {
    let failed: Vec<(u32, u64)> = {
        let st = ctx.state.borrow();
        st.rndv_recvs
            .iter()
            .filter(|(&(src, _), r)| r.status.is_none() && ctx.world.is_dead(src as usize))
            .map(|(&k, _)| k)
            .collect()
    };
    for (src, rndv) in failed {
        let done = {
            let mut st = ctx.state.borrow_mut();
            let Some(r) = st.rndv_recvs.get_mut(&(src, rndv)) else { continue };
            let mut status = StatusCore::success(src as i32, r.tag, r.received.min(r.cap));
            status.error = crate::abi::errors::MPI_ERR_PROC_FAILED;
            match r.rid {
                Some(rid) => {
                    st.rndv_recvs.remove(&(src, rndv));
                    Some((rid, status))
                }
                None => {
                    // Inline blocking path: park the error status for
                    // `take_rndv_status` to collect.
                    r.status = Some(status);
                    None
                }
            }
        };
        ctx.obs.note_op_failed_proc();
        if let Some((rid, status)) = done {
            if let Some(req) = ctx.tables.borrow_mut().reqs.get_mut(rid.0) {
                req.state = ReqState::Complete(status);
            }
        }
    }
}

/// Retry deferred sends. Queues are keyed per destination: a
/// still-full ring parks only that destination's queue — traffic to
/// every other rank keeps flowing (no head-of-line blocking).
fn flush_pending_sends(ctx: &RankCtx) {
    let mut st = ctx.state.borrow_mut();
    if st.pending_sends.is_empty() {
        return;
    }
    let fabric = &ctx.world.fabric;
    let world = &ctx.world;
    st.pending_sends.retain(|&dst, q| {
        if world.is_dead(dst) {
            return false; // messages to a dead process are discarded
        }
        while let Some(env) = q.pop_front() {
            if let Err(env) = fabric.try_send(dst, env) {
                q.push_front(env);
                break; // this destination is still full; others continue
            }
        }
        !q.is_empty()
    });
}

/// Drain every inbound envelope and route it straight into the matching
/// index: an arrival that matches a posted receive is delivered
/// immediately; the rest are filed as unexpected (indexed by
/// `(context, src, tag)` for the O(1) exact-match lookup).
fn drain_fabric(ctx: &RankCtx) {
    if ctx.world.fabric.inbound_empty(ctx.rank) {
        return;
    }
    let mut inbox = std::mem::take(&mut ctx.state.borrow_mut().inbox);
    ctx.world.fabric.poll_into(ctx.rank, &mut inbox);
    for env in inbox.drain(..) {
        route_arrival(ctx, env);
    }
    ctx.state.borrow_mut().inbox = inbox;
}

/// Route one arrival: acks feed the Ssend ack set; CTS credits feed the
/// sender's streams; chunks feed the receiver's streams; matchable
/// envelopes (eager, eager-sync, RTS) match against the posted side or
/// land in the unexpected index.
fn route_arrival(ctx: &RankCtx, env: Envelope) {
    let matched = {
        let mut st = ctx.state.borrow_mut();
        match env.kind {
            MsgKind::SsendAck => {
                st.ssend_acks.insert(env.seq);
                return;
            }
            MsgKind::Cts { rndv, credit } => {
                if let Some(s) = st.rndv_sends.get_mut(&rndv) {
                    if credit > s.credit {
                        s.credit = credit;
                    }
                }
                return;
            }
            MsgKind::RndvData { rndv, offset } => {
                drop(st);
                rndv_data_arrive(ctx, env.src, rndv, offset, env.payload);
                return;
            }
            MsgKind::Eager | MsgKind::EagerSync | MsgKind::Rts { .. } => {
                st.match_index.arrive(env)
            }
        }
    };
    if let Some((rid, env)) = matched {
        deliver(ctx, rid, env);
    }
}

/// Copy a matched message into the receive buffer and complete the
/// request — or, for a matched RTS, open the rendezvous stream that will
/// complete it once fully consumed.
fn deliver(ctx: &RankCtx, rid: ReqId, env: Envelope) {
    let (buf, count, dt) = {
        let t = ctx.tables.borrow();
        let Some(req) = t.reqs.get(rid.0) else { return };
        let ReqKind::Recv { buf, count, dt, .. } = req.kind else { return };
        (buf, count, dt)
    };
    if matches!(env.kind, MsgKind::Rts { .. }) {
        begin_rndv_recv(ctx, Some(rid), &env, buf, count, dt);
        return;
    }
    let status = deliver_inline(ctx, env, buf, count, dt);
    if let Some(req) = ctx.tables.borrow_mut().reqs.get_mut(rid.0) {
        req.state = ReqState::Complete(status);
    }
}

/// Unpack a matched envelope into a user buffer and build its status —
/// the shared tail of the request path ([`deliver`]) and the no-request
/// blocking-recv fast path ([`crate::core::engine`]). Also acks
/// synchronous sends (the message is matched the moment it is consumed).
pub(crate) fn deliver_inline(
    ctx: &RankCtx,
    env: Envelope,
    buf: usize,
    count: usize,
    dt: DtId,
) -> StatusCore {
    trace(ctx, TraceKind::Match, env.src, env.tag as u32);
    let status = {
        let t = ctx.tables.borrow();
        let data = env.payload.as_slice();
        // Capacity in packed bytes of the posted buffer.
        let cap = t.dtypes.get(dt.0).map(|o| o.size * count).unwrap_or(0);
        let truncated = data.len() > cap;
        let take = data.len().min(cap);
        let consumed =
            super::datatype::pack::unpack(&t.dtypes, &data[..take], buf as *mut u8, count, dt)
                .unwrap_or(0);
        let mut status = StatusCore::success(env.src as i32, env.tag, consumed as u64);
        if truncated {
            status.error = crate::abi::errors::MPI_ERR_TRUNCATE;
        }
        status
    };
    // Ack synchronous sends now that the message is matched.
    if env.kind == MsgKind::EagerSync {
        let ack = Envelope {
            src: ctx.rank as u32,
            context: env.context,
            tag: env.tag,
            kind: MsgKind::SsendAck,
            seq: env.seq,
            payload: Payload::empty(),
        };
        enqueue_send(ctx, env.src as usize, ack);
    }
    status
}

/// Open a rendezvous send: file the stream state and post the RTS (which
/// travels the ordinary channel, so it keeps FIFO order with eager
/// traffic on the same `(context, src, tag)`). Returns the stream id the
/// request completes on. Chunks start flowing when the receiver's CTS
/// lands — until then nothing but the control envelope is buffered
/// (except for plan-less types, which pre-pack once as a fallback).
pub(crate) fn begin_rndv_send(
    ctx: &RankCtx,
    dst: usize,
    context: u32,
    tag: i32,
    buf: *const u8,
    count: usize,
    dt: DtId,
) -> RC<u64> {
    let (total, has_plan) = {
        let t = ctx.tables.borrow();
        let obj = t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?;
        ((obj.size * count) as u64, obj.plan.is_some())
    };
    let packed = if has_plan {
        None
    } else {
        let t = ctx.tables.borrow();
        let mut v = Vec::with_capacity(total as usize);
        super::datatype::pack::pack(&t.dtypes, buf, count, dt, &mut v)?;
        Some(v)
    };
    let (rndv, seq) = {
        let mut st = ctx.state.borrow_mut();
        let rndv = st.next_rndv_id;
        st.next_rndv_id += 1;
        let seq = st.send_seq;
        st.send_seq += 1;
        st.rndv_sends.insert(
            rndv,
            RndvSend {
                dst,
                context,
                tag,
                buf: buf as usize,
                count,
                dt,
                total,
                sent: 0,
                credit: 0,
                packed,
            },
        );
        (rndv, seq)
    };
    ctx.obs.rndv_msgs.set(ctx.obs.rndv_msgs.get() + 1);
    ctx.obs.rndv_bytes.set(ctx.obs.rndv_bytes.get() + total);
    trace(ctx, TraceKind::Rts, dst as u32, clamp32(total));
    let rts = Envelope {
        src: ctx.rank as u32,
        context,
        tag,
        kind: MsgKind::Rts { total, rndv },
        seq,
        payload: Payload::empty(),
    };
    enqueue_send(ctx, dst, rts);
    Ok(rndv)
}

/// Whether outbound rendezvous stream `rndv` is still in flight (the
/// blocking-send spin condition; nonblocking sends check it via
/// [`finish_if_done`]).
pub(crate) fn rndv_send_active(ctx: &RankCtx, rndv: u64) -> bool {
    ctx.state.borrow().rndv_sends.contains_key(&rndv)
}

/// Advance every outbound rendezvous stream: pack and enqueue chunks up
/// to the granted credit. A destination with parked traffic is skipped
/// this tick (its queue drains first — and other destinations' streams
/// keep flowing, so chunk backpressure never head-of-line-blocks). A
/// stream whose last chunk is enqueued is removed — that completes the
/// send request.
fn pump_rndv_sends(ctx: &RankCtx) {
    let ids: Vec<u64> = {
        let st = ctx.state.borrow();
        if st.rndv_sends.is_empty() {
            return;
        }
        st.rndv_sends.keys().copied().collect()
    };
    for rndv in ids {
        loop {
            // Decide the next chunk (or stop) under a short borrow.
            let step = {
                let st = ctx.state.borrow();
                let Some(s) = st.rndv_sends.get(&rndv) else { break };
                if ctx.world.is_dead(s.dst) {
                    // Leave the entry in place: the completion check fails
                    // the send request with MPI_ERR_PROC_FAILED (removing
                    // it here would complete the send successfully).
                    break;
                }
                if st.pending_sends.contains_key(&s.dst) {
                    None // destination parked; retry next progress tick
                } else {
                    let limit = s.total.min(s.credit);
                    if s.sent >= limit {
                        None
                    } else {
                        let len = ((limit - s.sent).min(RNDV_CHUNK as u64)) as usize;
                        Some((s.dst, s.context, s.tag, s.buf, s.count, s.dt, s.sent, len))
                    }
                }
            };
            let Some((dst, context, tag, buf, count, dt, sent, len)) = step else { break };
            let payload = {
                let st = ctx.state.borrow();
                let s = st.rndv_sends.get(&rndv).unwrap();
                if let Some(p) = &s.packed {
                    Payload::from_slice(&p[sent as usize..sent as usize + len])
                } else {
                    let t = ctx.tables.borrow();
                    let mut v = Vec::with_capacity(len);
                    let planned = super::datatype::pack::pack_range(
                        &t.dtypes,
                        buf as *const u8,
                        count,
                        dt,
                        sent as usize,
                        len,
                        &mut v,
                    )
                    .unwrap_or(false);
                    debug_assert!(planned, "plan-less types pre-pack at begin_rndv_send");
                    Payload::from_vec(v)
                }
            };
            let env = Envelope {
                src: ctx.rank as u32,
                context,
                tag,
                kind: MsgKind::RndvData { rndv, offset: sent },
                seq: 0,
                payload,
            };
            enqueue_send(ctx, dst, env);
            ctx.world.note_rndv_enqueue(len as u64);
            let mut st = ctx.state.borrow_mut();
            if let Some(s) = st.rndv_sends.get_mut(&rndv) {
                s.sent += len as u64;
                if s.sent >= s.total {
                    st.rndv_sends.remove(&rndv); // send complete
                    break;
                }
            }
        }
    }
}

/// Open the receive side of a rendezvous stream from a matched RTS:
/// file the stream state and grant the initial credit window. `rid:
/// None` is the blocking-recv inline path (poll [`take_rndv_status`]).
pub(crate) fn begin_rndv_recv(
    ctx: &RankCtx,
    rid: Option<ReqId>,
    env: &Envelope,
    buf: usize,
    count: usize,
    dt: DtId,
) {
    let MsgKind::Rts { total, rndv } = env.kind else { return };
    if total == 0 {
        // Defensive: senders never open a zero-byte stream (empty
        // messages stay eager), but complete cleanly if one appears.
        let status = StatusCore::success(env.src as i32, env.tag, 0);
        match rid {
            Some(rid) => {
                if let Some(req) = ctx.tables.borrow_mut().reqs.get_mut(rid.0) {
                    req.state = ReqState::Complete(status);
                }
            }
            None => {
                let mut st = ctx.state.borrow_mut();
                st.rndv_recvs.insert(
                    (env.src, rndv),
                    RndvRecv {
                        rid: None,
                        buf,
                        count,
                        dt,
                        cap: 0,
                        total: 0,
                        received: 0,
                        granted: 0,
                        tag: env.tag,
                        context: env.context,
                        staging: None,
                        status: Some(status),
                    },
                );
            }
        }
        return;
    }
    let (cap, has_plan) = {
        let t = ctx.tables.borrow();
        t.dtypes
            .get(dt.0)
            .map(|o| ((o.size * count) as u64, o.plan.is_some()))
            .unwrap_or((0, true))
    };
    let staging = if has_plan { None } else { Some(vec![0u8; total.min(cap) as usize]) };
    let granted = total.min(RNDV_WINDOW_BYTES);
    ctx.state.borrow_mut().rndv_recvs.insert(
        (env.src, rndv),
        RndvRecv {
            rid,
            buf,
            count,
            dt,
            cap,
            total,
            received: 0,
            granted,
            tag: env.tag,
            context: env.context,
            staging,
            status: None,
        },
    );
    trace(ctx, TraceKind::Cts, env.src, clamp32(granted));
    let cts = Envelope {
        src: ctx.rank as u32,
        context: env.context,
        tag: env.tag,
        kind: MsgKind::Cts { rndv, credit: granted },
        seq: 0,
        payload: Payload::empty(),
    };
    enqueue_send(ctx, env.src as usize, cts);
}

/// Consume one rendezvous chunk: scatter it into the user buffer (or
/// staging) at its packed offset, re-grant credit when the window runs
/// low, and complete the receive when the stream is fully consumed.
fn rndv_data_arrive(ctx: &RankCtx, src: u32, rndv: u64, offset: u64, payload: Payload) {
    let len = payload.len() as u64;
    ctx.world.note_rndv_consume(len);
    enum After {
        Nothing,
        Regrant { dst: usize, context: u32, tag: i32, credit: u64 },
        Complete {
            rid: Option<ReqId>,
            staging: Option<Vec<u8>>,
            buf: usize,
            count: usize,
            dt: DtId,
            status: StatusCore,
        },
    }
    let after = {
        let mut st = ctx.state.borrow_mut();
        // Unknown stream (request freed mid-stream): drop the chunk.
        let Some(r) = st.rndv_recvs.get_mut(&(src, rndv)) else { return };
        let data = payload.as_slice();
        let take = if offset < r.cap { ((r.cap - offset).min(len)) as usize } else { 0 };
        if take > 0 {
            if let Some(stg) = &mut r.staging {
                stg[offset as usize..offset as usize + take].copy_from_slice(&data[..take]);
            } else {
                let t = ctx.tables.borrow();
                let _ = super::datatype::pack::unpack_range(
                    &t.dtypes,
                    &data[..take],
                    r.buf as *mut u8,
                    r.count,
                    r.dt,
                    offset as usize,
                );
            }
        }
        r.received += len;
        if r.received >= r.total {
            let mut status = StatusCore::success(src as i32, r.tag, r.total.min(r.cap));
            if r.total > r.cap {
                status.error = crate::abi::errors::MPI_ERR_TRUNCATE;
            }
            let staging = r.staging.take();
            let (rid, buf, count, dt) = (r.rid, r.buf, r.count, r.dt);
            if rid.is_some() {
                st.rndv_recvs.remove(&(src, rndv));
            }
            After::Complete { rid, staging, buf, count, dt, status }
        } else if r.granted < r.total && r.granted - r.received < RNDV_REGRANT_BYTES {
            let credit = r.total.min(r.received + RNDV_WINDOW_BYTES);
            r.granted = credit;
            After::Regrant { dst: src as usize, context: r.context, tag: r.tag, credit }
        } else {
            After::Nothing
        }
    };
    match after {
        After::Nothing => {}
        After::Regrant { dst, context, tag, credit } => {
            trace(ctx, TraceKind::ChunkGrant, src, clamp32(credit));
            let cts = Envelope {
                src: ctx.rank as u32,
                context,
                tag,
                kind: MsgKind::Cts { rndv, credit },
                seq: 0,
                payload: Payload::empty(),
            };
            enqueue_send(ctx, dst, cts);
        }
        After::Complete { rid, staging, buf, count, dt, mut status } => {
            if let Some(stg) = staging {
                // Plan-less fallback: one-shot scatter of the staged stream.
                let t = ctx.tables.borrow();
                let consumed =
                    super::datatype::pack::unpack(&t.dtypes, &stg, buf as *mut u8, count, dt)
                        .unwrap_or(0);
                status.count_bytes = consumed as u64;
            }
            match rid {
                Some(rid) => {
                    if let Some(req) = ctx.tables.borrow_mut().reqs.get_mut(rid.0) {
                        req.state = ReqState::Complete(status);
                    }
                }
                None => {
                    if let Some(r) = ctx.state.borrow_mut().rndv_recvs.get_mut(&(src, rndv)) {
                        r.status = Some(status);
                    }
                }
            }
        }
    }
}

/// Poll-and-take the completion status of an inline (no-request)
/// rendezvous receive — the blocking-recv spin partner of
/// [`begin_rndv_recv`] with `rid: None`.
pub(crate) fn take_rndv_status(ctx: &RankCtx, src: u32, rndv: u64) -> Option<StatusCore> {
    let mut st = ctx.state.borrow_mut();
    if st.rndv_recvs.get(&(src, rndv)).is_some_and(|r| r.status.is_some()) {
        return st.rndv_recvs.remove(&(src, rndv)).and_then(|r| r.status);
    }
    None
}

/// Send an envelope, preserving per-destination FIFO even under
/// backpressure (a destination's deferred envelopes drain before new
/// ones to it; other destinations are unaffected).
pub(crate) fn enqueue_send(ctx: &RankCtx, dst: usize, env: Envelope) {
    if ctx.world.is_dead(dst) {
        return; // messages to a dead process are discarded
    }
    let mut st = ctx.state.borrow_mut();
    if let Some(q) = st.pending_sends.get_mut(&dst) {
        // Deferred traffic to this destination exists: queue behind it.
        q.push_back(env);
        ctx.obs.note_pending_depth(q.len() as u64);
        return;
    }
    if let Err(env) = ctx.world.fabric.try_send(dst, env) {
        let mut q = std::collections::VecDeque::with_capacity(4);
        q.push_back(env);
        st.pending_sends.insert(dst, q);
        ctx.obs.note_pending_depth(1);
    }
}

/// Poll a request's completion state; applies one progress cycle first.
pub(crate) fn poll_complete(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    progress(ctx);
    finish_if_done(ctx, rid)
}

/// Check (without progressing) whether `rid` is complete, resolving
/// Ssend acks. Schedule-backed (collective) requests complete inside
/// [`progress`] — here they are simply pending until their status lands.
/// Inactive persistent requests count as complete with an empty status
/// (MPI 3.0 §3.7.3: wait on an inactive request returns immediately).
pub(crate) fn finish_if_done(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    enum Next {
        Done(StatusCore),
        Pending,
        CheckSsend { sync_id: u64, dst: usize },
        CheckRndv(u64),
        CheckRecv { src: i32, context: u32 },
    }
    let next = {
        let t = ctx.tables.borrow();
        let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
        match (&req.state, &req.kind) {
            (ReqState::Complete(s), _) => Next::Done(*s),
            (ReqState::Inactive, _) => Next::Done(StatusCore::empty()),
            (ReqState::Active, ReqKind::Ssend { sync_id, dst }) => {
                Next::CheckSsend { sync_id: *sync_id, dst: *dst }
            }
            (ReqState::Active, ReqKind::RndvSend { rndv }) => Next::CheckRndv(*rndv),
            (ReqState::Active, ReqKind::Recv { src, context, .. })
                if ctx.world.any_dead() || ctx.world.is_revoked(*context) =>
            {
                Next::CheckRecv { src: *src, context: *context }
            }
            (ReqState::Active, _) => Next::Pending,
        }
    };
    match next {
        Next::Done(s) => Ok(Some(s)),
        Next::Pending => Ok(None),
        Next::CheckSsend { sync_id, dst } => {
            let acked = ctx.state.borrow_mut().ssend_acks.remove(&sync_id);
            if acked {
                let s = StatusCore::empty();
                ctx.tables.borrow_mut().reqs.get_mut(rid.0).unwrap().state =
                    ReqState::Complete(s);
                Ok(Some(s))
            } else if ctx.world.is_dead(dst) {
                // The ack can never come: ULFM completes the send in error.
                ctx.obs.note_op_failed_proc();
                let mut s = StatusCore::empty();
                s.error = crate::abi::errors::MPI_ERR_PROC_FAILED;
                ctx.tables.borrow_mut().reqs.get_mut(rid.0).unwrap().state =
                    ReqState::Complete(s);
                Ok(Some(s))
            } else {
                Ok(None)
            }
        }
        Next::CheckRndv(rndv) => {
            let fail = {
                let st = ctx.state.borrow();
                match st.rndv_sends.get(&rndv) {
                    None => {
                        // Stream fully enqueued: the send completed.
                        let s = StatusCore::empty();
                        ctx.tables.borrow_mut().reqs.get_mut(rid.0).unwrap().state =
                            ReqState::Complete(s);
                        return Ok(Some(s));
                    }
                    Some(s) if ctx.world.is_dead(s.dst) => {
                        Some(crate::abi::errors::MPI_ERR_PROC_FAILED)
                    }
                    Some(s) if ctx.world.is_revoked(s.context) => {
                        Some(crate::abi::errors::MPI_ERR_REVOKED)
                    }
                    Some(_) => None,
                }
            };
            match fail {
                Some(class) => {
                    if class == crate::abi::errors::MPI_ERR_PROC_FAILED {
                        ctx.obs.note_op_failed_proc();
                    }
                    ctx.state.borrow_mut().rndv_sends.remove(&rndv);
                    let mut s = StatusCore::empty();
                    s.error = class;
                    ctx.tables.borrow_mut().reqs.get_mut(rid.0).unwrap().state =
                        ReqState::Complete(s);
                    Ok(Some(s))
                }
                None => Ok(None),
            }
        }
        Next::CheckRecv { src, context } => {
            if ctx.world.is_revoked(context) {
                let mut s = StatusCore::empty();
                s.error = crate::abi::errors::MPI_ERR_REVOKED;
                return Ok(Some(fail_recv(ctx, rid, s)));
            }
            // A receive already matched to a live rendezvous stream is
            // progressing — let it complete (a dead sender's streams were
            // failed by `fail_rndv_from_dead` before we got here).
            let matched_stream =
                ctx.state.borrow().rndv_recvs.values().any(|r| r.rid == Some(rid));
            if matched_stream {
                return Ok(None);
            }
            if src == crate::abi::constants::MPI_ANY_SOURCE {
                // ULFM: a wildcard receive cannot block while an
                // unacknowledged member failure exists — any dead rank
                // could have been its matching sender. The request stays
                // Active; the wait surfaces the *pending* class.
                if super::comm::failure_pending_on_context(ctx, context) {
                    return Err(err!(MPI_ERR_PROC_FAILED_PENDING));
                }
                Ok(None)
            } else if src >= 0 && ctx.world.is_dead(src as usize) {
                ctx.obs.note_op_failed_proc();
                let mut s = StatusCore::empty();
                s.source = src;
                s.error = crate::abi::errors::MPI_ERR_PROC_FAILED;
                Ok(Some(fail_recv(ctx, rid, s)))
            } else {
                Ok(None)
            }
        }
    }
}

/// Complete an unmatched receive in error (dead peer or revoked comm):
/// withdraw it from the matching index so no later arrival can match a
/// request the application is about to retire, then record the status.
fn fail_recv(ctx: &RankCtx, rid: ReqId, status: StatusCore) -> StatusCore {
    ctx.state.borrow_mut().match_index.withdraw(rid);
    if let Some(req) = ctx.tables.borrow_mut().reqs.get_mut(rid.0) {
        req.state = ReqState::Complete(status);
    }
    status
}

/// Consume a completed request in wait/test: persistent requests return
/// to Inactive and stay in the table (the lifecycle's back edge);
/// nonpersistent requests are deallocated.
pub(crate) fn retire(ctx: &RankCtx, rid: ReqId) {
    trace(ctx, TraceKind::Complete, rid.0, 0);
    let mut t = ctx.tables.borrow_mut();
    let persistent = t.reqs.get(rid.0).map(|r| r.persist.is_some()).unwrap_or(false);
    if persistent {
        if let Some(req) = t.reqs.get_mut(rid.0) {
            req.state = ReqState::Inactive;
        }
    } else {
        t.reqs.remove(rid.0);
    }
}

/// Whether `rid` names a persistent request (ABI layers use this to keep
/// the user's handle valid across wait/test instead of nulling it).
pub(crate) fn is_persistent(ctx: &RankCtx, rid: ReqId) -> bool {
    ctx.tables.borrow().reqs.get(rid.0).map(|r| r.persist.is_some()).unwrap_or(false)
}

/// Whether `rid` is an Inactive persistent request. Waitany/testany must
/// *ignore* inactive handles rather than report them complete (MPI 3.0
/// §3.7.5 — only wait/test/waitall return empty statuses for them).
pub(crate) fn is_inactive(ctx: &RankCtx, rid: ReqId) -> RC<bool> {
    let t = ctx.tables.borrow();
    let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
    Ok(req.state == ReqState::Inactive)
}

/// Block until `rid` completes; retire it; return its status.
pub(crate) fn wait_one(ctx: &RankCtx, rid: ReqId) -> RC<StatusCore> {
    loop {
        if let Some(s) = poll_complete(ctx, rid)? {
            retire(ctx, rid);
            return Ok(s);
        }
        std::thread::yield_now();
    }
}

/// Nonblocking completion check; retires on completion (`MPI_Test`).
pub(crate) fn test_one(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    match poll_complete(ctx, rid)? {
        Some(s) => {
            retire(ctx, rid);
            Ok(Some(s))
        }
        None => Ok(None),
    }
}

/// `MPI_Cancel` — supported for unmatched receives (marks cancelled).
pub fn cancel(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| {
        let is_recv_pending = {
            let t = ctx.tables.borrow();
            let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
            matches!(req.kind, ReqKind::Recv { .. }) && req.state == ReqState::Active
        };
        // A receive bound to an in-flight rendezvous stream has already
        // matched — MPI semantics say it must complete normally, so
        // cancel is a no-op for it (same as a matched eager receive).
        let rndv_bound =
            ctx.state.borrow().rndv_recvs.values().any(|r| r.rid == Some(rid));
        if is_recv_pending && !rndv_bound {
            ctx.state.borrow_mut().match_index.withdraw(rid);
            let mut t = ctx.tables.borrow_mut();
            let req = t.reqs.get_mut(rid.0).unwrap();
            let mut s = StatusCore::empty();
            s.cancelled = true;
            req.state = ReqState::Complete(s);
        }
        // Sends: cancel is best-effort; eager sends already completed.
        Ok(())
    })
}

/// `MPI_Request_free`.
///
/// Freeing an *active* schedule-backed request is rejected (dropping the
/// schedule would strand its unexecuted send steps and deadlock peers),
/// as is freeing a persistent request that is not Inactive — a started
/// persistent request stays "in use" until wait/test collects it, even
/// if the operation already finished internally (MPI-4 §3.9). **Inactive
/// persistent requests free cleanly** — including persistent
/// collectives, whose retained schedule is simply dropped with the
/// request.
pub fn request_free(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| {
        let withdraw = {
            let t = ctx.tables.borrow();
            let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
            let active = req.state == ReqState::Active;
            if req.persist.is_some() && req.state != ReqState::Inactive {
                return Err(err!(MPI_ERR_REQUEST));
            }
            if active && matches!(req.kind, ReqKind::Sched(_)) {
                return Err(err!(MPI_ERR_REQUEST));
            }
            active && matches!(req.kind, ReqKind::Recv { .. })
        };
        // Freeing a still-posted receive: withdraw it from the matching
        // engine first, so the freed slot can be recycled without a stale
        // posted entry matching a foreign message into it.
        if withdraw {
            ctx.state.borrow_mut().match_index.withdraw(rid);
        }
        ctx.tables.borrow_mut().reqs.remove(rid.0).map(|_| ()).ok_or(err!(MPI_ERR_REQUEST))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::transport::{MsgKind, Payload, SPSC_CAPACITY};
    use crate::core::world::{bind_rank, test_world, unbind_rank};

    fn env(tag: i32) -> Envelope {
        Envelope {
            src: 0,
            context: 0,
            tag,
            kind: MsgKind::Eager,
            seq: 0,
            payload: Payload::empty(),
        }
    }

    /// Deterministic pin of the head-of-line-blocking fix: with *both*
    /// destination rings full and envelopes parked for each, draining
    /// ring 0→2 alone must let dst-2's deferred envelopes flow on the
    /// next flush even though dst-1's stay stuck. (The seed's single
    /// flush queue stopped at the first full destination, so dst-2
    /// traffic parked behind dst-1 entries never moved.)
    #[test]
    fn flush_is_keyed_per_destination() {
        std::thread::spawn(|| {
            let w = test_world(3);
            let ctx = bind_rank(w, 0);
            for _ in 0..SPSC_CAPACITY + 2 {
                enqueue_send(&ctx, 1, env(4));
                enqueue_send(&ctx, 2, env(6));
            }
            {
                let st = ctx.state.borrow();
                assert_eq!(st.pending_sends.get(&1).map(|q| q.len()), Some(2));
                assert_eq!(st.pending_sends.get(&2).map(|q| q.len()), Some(2));
            }
            // Play rank 2's role (single-threaded test): drain its ring.
            let mut sink = Vec::new();
            ctx.world.fabric.poll_into(2, &mut sink);
            assert_eq!(sink.len(), SPSC_CAPACITY);
            flush_pending_sends(&ctx);
            {
                let st = ctx.state.borrow();
                assert!(st.pending_sends.get(&2).is_none(), "dst-2 queue must drain");
                assert_eq!(
                    st.pending_sends.get(&1).map(|q| q.len()),
                    Some(2),
                    "dst-1 still parked (its ring is still full)"
                );
            }
            unbind_rank();
        })
        .join()
        .unwrap();
    }

    /// A send to a destination with parked traffic queues behind it
    /// (per-destination FIFO); sends to other destinations go straight
    /// to the fabric.
    #[test]
    fn enqueue_bypasses_other_destinations_backpressure() {
        std::thread::spawn(|| {
            let w = test_world(3);
            let ctx = bind_rank(w, 0);
            for _ in 0..SPSC_CAPACITY + 1 {
                enqueue_send(&ctx, 1, env(4));
            }
            enqueue_send(&ctx, 2, env(6));
            {
                let st = ctx.state.borrow();
                assert_eq!(st.pending_sends.get(&1).map(|q| q.len()), Some(1));
                assert!(st.pending_sends.get(&2).is_none(), "dst 2 must not be parked");
            }
            assert!(!ctx.world.fabric.inbound_empty(2), "dst-2 envelope reached the fabric");
            unbind_rank();
        })
        .join()
        .unwrap();
    }
}
