//! Acceptance: the **sessions-only** halo exchange — `MPI_Session_init`
//! → `mpi://WORLD` pset → group → `MPI_Comm_create_from_group`, never
//! calling `MPI_Init` — produces bitwise-identical results to the
//! world-model run, in every exchange mode (sendrecv / persistent /
//! RMA), under every ABI configuration, on both transports.

use mpi_abi::api::MpiAbi;
use mpi_abi::apps::halo::{jacobi, jacobi_sessions, HaloMode, HaloParams};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::core::transport::TransportKind;
use mpi_abi::launcher::{run_job_ok, JobSpec};

const RANKS: usize = 3;
const N: usize = 48;
const ITERS: usize = 8;

struct Halo {
    transport: TransportKind,
    mode: HaloMode,
    sessions: bool,
}

impl AbiApp<f64> for Halo {
    fn run<A: MpiAbi>(self) -> f64 {
        let (mode, sessions) = (self.mode, self.sessions);
        let out = run_job_ok(JobSpec::new(RANKS).with_transport(self.transport), move |_| {
            let p = HaloParams { n: N, iters: ITERS, mode };
            if sessions {
                // No MPI_Init / MPI_Finalize anywhere on this path.
                let (_, global) = jacobi_sessions::<A>(p);
                global
            } else {
                A::init();
                let (_, global) = jacobi::<A>(p);
                A::finalize();
                global
            }
        });
        out[0]
    }
}

#[test]
fn sessions_only_halo_bitwise_matches_world_model() {
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        // Reference: the world model, sendrecv, native standard ABI.
        let reference = with_abi(
            AbiConfig::NativeAbi,
            Halo { transport, mode: HaloMode::Sendrecv, sessions: false },
        );
        assert!(reference > 0.0, "heat must have diffused");
        for abi in AbiConfig::ALL {
            for mode in [HaloMode::Sendrecv, HaloMode::Persistent, HaloMode::Rma] {
                let got = with_abi(abi, Halo { transport, mode, sessions: true });
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "sessions-only {} / {} on {} transport diverged: {got} vs {reference}",
                    abi.name(),
                    mode.name(),
                    transport.name(),
                );
            }
        }
    }
}
