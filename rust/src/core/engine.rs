//! The engine facade: MPI semantics over engine ids.
//!
//! Every implementation ABI (mpich-like, ompi-like, native standard ABI)
//! is a handle-representation shim over exactly these functions, so the
//! *semantics* are shared and the benchmarks measure only representation
//! and translation costs — the paper's subject.

use super::comm::{comm_snapshot, finish_predefined as finish_comms};
use super::group::finish_predefined as finish_groups;
use super::request::{
    enqueue_send, new_persistent, new_request, post_recv, progress, test_one, wait_one,
    PersistSpec, ReqKind, ReqState, StatusCore,
};
use super::transport::{Envelope, MsgKind, Payload};
use super::world::{try_ctx, with_ctx, RankCtx};
use super::{err, CommId, DtId, MpiError, ReqId, RC};
use crate::abi::constants::{MPI_ANY_SOURCE, MPI_ANY_TAG, MPI_PROC_NULL, MPI_UNDEFINED};

// ---------------------------------------------------------------------------
// Init / finalize / environment
// ---------------------------------------------------------------------------

/// Size the predefined world/self/bootstrap groups and comms exactly
/// once per rank — run by whichever of `MPI_Init` / `MPI_Session_init`
/// happens first (world and sessions share the tables).
pub(crate) fn ensure_world_objects(ctx: &RankCtx) {
    if ctx.predef_sized.get() {
        return;
    }
    let (size, rank) = (ctx.world.size, ctx.rank);
    {
        let mut t = ctx.tables.borrow_mut();
        finish_groups(&mut t.groups, size, rank);
        finish_comms(&mut t.comms, size, rank);
    }
    ctx.predef_sized.set(true);
}

/// `MPI_Init` (the world model). The launcher has already bound the rank
/// context; this sizes the predefined objects (if no session got there
/// first) and opens one epoch of the shared init refcount.
pub fn init() -> RC<()> {
    with_ctx(|ctx| {
        if ctx.initialized.get() {
            return Err(err!(MPI_ERR_OTHER)); // double init
        }
        ensure_world_objects(ctx);
        ctx.initialized.set(true);
        ctx.note_init();
        Ok(())
    })
}

/// `MPI_Initialized` — callable at any time. Sessions-aware: true once
/// *any* initialization — `MPI_Init` or `MPI_Session_init` — has
/// happened on this process, and it never resets. (MPI-4.1 scopes
/// these predicates to the world model; this ABI deliberately pins the
/// refcounted, library-wide reading so coexisting models can probe
/// whether MPI is alive — the contract is written down in SPEC.md §6.)
pub fn initialized() -> bool {
    try_ctx(|ctx| ctx.map(|c| c.ever_inited.get()).unwrap_or(false))
}

/// `MPI_Finalized` — callable at any time. Sessions-aware, like
/// [`initialized`]: true only when the library was initialized at some
/// point and *every* initialization epoch — the world model and all
/// sessions — has since been finalized. A world finalize with a
/// session still active does not finalize the library.
pub fn finalized() -> bool {
    try_ctx(|ctx| ctx.map(|c| c.ever_inited.get() && c.active_inits.get() == 0).unwrap_or(false))
}

/// `MPI_Finalize` (the world model): quiesce (barrier over world), mark
/// the world model finalized, and close its epoch of the shared init
/// refcount. Sessions opened before or during the world epoch survive.
pub fn finalize() -> RC<()> {
    super::collectives::barrier(super::reserved::COMM_WORLD)?;
    with_ctx(|ctx| {
        if !ctx.initialized.get() || ctx.finalized.get() {
            return Err(err!(MPI_ERR_OTHER));
        }
        ctx.finalized.set(true);
        ctx.note_finalize_one();
        ctx.world.note_finalize();
        // Merge this rank's trace ring into the world sink while the
        // job is still quiesced (unbind_rank re-flushes as a catch-all
        // for sessions-only runs; the flush is idempotent).
        super::obs::flush_trace(ctx);
        Ok(())
    })
}

/// `MPI_Abort`.
pub fn abort(code: i32) -> RC<()> {
    with_ctx(|ctx| {
        ctx.world.abort(code);
        std::panic::panic_any(super::world::AbortUnwind(code));
    })
}

/// `MPI_Wtime`.
pub fn wtime() -> f64 {
    try_ctx(|ctx| ctx.map(|c| c.world.wtime()).unwrap_or(0.0))
}

/// `MPI_Wtick`.
pub fn wtick() -> f64 {
    1e-9
}

/// `MPI_Get_processor_name`.
pub fn get_processor_name() -> String {
    let host = std::env::var("HOSTNAME").unwrap_or_else(|_| "localhost".to_string());
    let rank = super::world::current_rank().unwrap_or(0);
    format!("{host}-rank{rank}")
}

/// `MPI_Get_version`.
pub fn get_version() -> (i32, i32) {
    (crate::abi::constants::MPI_VERSION, crate::abi::constants::MPI_SUBVERSION)
}

/// `MPI_Get_library_version`.
pub fn get_library_version() -> String {
    crate::LIBRARY_VERSION.to_string()
}

// ---------------------------------------------------------------------------
// Point-to-point
// ---------------------------------------------------------------------------

/// Send mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendMode {
    /// `MPI_Send` / `MPI_Isend` (eager).
    Standard,
    /// `MPI_Ssend` / `MPI_Issend` (completes on match).
    Sync,
}

fn check_tag_send(tag: i32) -> RC<()> {
    if tag < 0 || tag > crate::abi::constants::TAG_UB_VALUE as i32 {
        return Err(err!(MPI_ERR_TAG));
    }
    Ok(())
}

fn check_rank(r: i32, size: usize, allow_any: bool) -> RC<()> {
    if r == MPI_PROC_NULL || (allow_any && r == MPI_ANY_SOURCE) {
        return Ok(());
    }
    if r < 0 || r as usize >= size {
        return Err(err!(MPI_ERR_RANK));
    }
    Ok(())
}

/// Pack `count` items of `dt` at `buf` into a payload (fast path for
/// contiguous layouts: single copy, inline for small messages).
fn pack_payload(ctx: &RankCtx, buf: *const u8, count: usize, dt: DtId) -> RC<Payload> {
    let t = ctx.tables.borrow();
    let obj = t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?;
    if obj.contiguous {
        let n = obj.size * count;
        let bytes = unsafe { std::slice::from_raw_parts(buf, n) };
        Ok(Payload::from_slice(bytes))
    } else {
        let mut v = Vec::new();
        super::datatype::pack::pack(&t.dtypes, buf, count, dt, &mut v)?;
        Ok(Payload::from_vec(v))
    }
}

/// Whether a send of `count` items of `dt` must go rendezvous: packed
/// size above this rank's eager/rendezvous threshold and non-empty.
/// (With threshold 0 every non-empty message goes rendezvous; empty
/// messages always stay eager — a zero-byte stream has nothing to
/// stream.) Shared by `isend_impl`, `send_fast`, and the persistent
/// start path so the protocol choice can never diverge between them.
fn rndv_switch(ctx: &RankCtx, count: usize, dt: DtId) -> RC<bool> {
    let total = {
        let t = ctx.tables.borrow();
        let obj = t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?;
        obj.size * count
    };
    Ok(total > 0 && total > ctx.state.borrow().rndv_threshold)
}

/// Validate and resolve a send's wire route — the **shared prelude** of
/// the slab path (`isend_impl`, `send_init`) and the zero-alloc fast
/// path (`send_fast`), so the `MPI_ERR_*` behavior of every path is one
/// piece of code and can never diverge. Callers handle `MPI_PROC_NULL`
/// first (its outcome differs per path).
fn route_send(ctx: &RankCtx, dest: i32, tag: i32, comm: CommId) -> RC<(usize, u32)> {
    check_tag_send(tag)?;
    let (size, dst, ctx_pt2pt) = super::comm::comm_route(ctx, comm, dest)?;
    check_rank(dest, size, false)?;
    if ctx.world.is_revoked(ctx_pt2pt) {
        return Err(err!(MPI_ERR_REVOKED));
    }
    let dst = dst.ok_or(err!(MPI_ERR_RANK))?;
    if ctx.world.is_dead(dst) {
        // ULFM: communication with a failed process raises
        // MPI_ERR_PROC_FAILED — failing at post time keeps the
        // fabric free of traffic nobody will drain.
        ctx.obs.note_op_failed_proc();
        return Err(err!(MPI_ERR_PROC_FAILED));
    }
    Ok((dst, ctx_pt2pt))
}

/// Validate and resolve a receive's matching key — shared by
/// `irecv_impl`, `recv_init`, and `recv_fast` for the same reason as
/// [`route_send`]. Returns the world-rank (or wildcard) source to match
/// and the pt2pt context plane. Wildcard source matches by *world* rank
/// of comm members; a concrete source is translated to its world rank
/// for envelope matching.
fn route_recv(ctx: &RankCtx, src: i32, tag: i32, comm: CommId) -> RC<(i32, u32)> {
    if tag != MPI_ANY_TAG {
        check_tag_send(tag)?;
    }
    let (size, src_world, ctx_pt2pt) = super::comm::comm_route(ctx, comm, src)?;
    check_rank(src, size, true)?;
    if ctx.world.is_revoked(ctx_pt2pt) {
        return Err(err!(MPI_ERR_REVOKED));
    }
    let src_match = if src == MPI_ANY_SOURCE {
        MPI_ANY_SOURCE
    } else {
        src_world.ok_or(err!(MPI_ERR_RANK))? as i32
    };
    Ok((src_match, ctx_pt2pt))
}

fn isend_impl(
    ctx: &RankCtx,
    buf: *const u8,
    count: usize,
    dt: DtId,
    dest: i32,
    tag: i32,
    comm: CommId,
    mode: SendMode,
) -> RC<ReqId> {
    if dest == MPI_PROC_NULL {
        return Ok(new_request(ctx, ReqKind::Send, ReqState::Complete(StatusCore::empty())));
    }
    let (dst_world, ctx_pt2pt) = route_send(ctx, dest, tag, comm)?;
    ctx.obs.sends_posted.set(ctx.obs.sends_posted.get() + 1);
    if rndv_switch(ctx, count, dt)? {
        // Rendezvous covers synchronous mode for free: the CTS implies
        // the receive matched, and the request completes only after the
        // full stream is out. (The rndv pvars bump inside
        // `begin_rndv_send`, shared by every rendezvous caller.)
        let rndv = super::request::begin_rndv_send(ctx, dst_world, ctx_pt2pt, tag, buf, count, dt)?;
        return Ok(new_request(ctx, ReqKind::RndvSend { rndv }, ReqState::Active));
    }
    let payload = pack_payload(ctx, buf, count, dt)?;
    ctx.obs.eager_msgs.set(ctx.obs.eager_msgs.get() + 1);
    ctx.obs.eager_bytes.set(ctx.obs.eager_bytes.get() + payload.len() as u64);
    let (kind, seq, sync_id) = send_wire_ids(ctx, mode == SendMode::Sync);
    let env = Envelope {
        src: ctx.rank as u32,
        context: ctx_pt2pt,
        tag,
        kind,
        seq,
        payload,
    };
    enqueue_send(ctx, dst_world, env);
    Ok(match sync_id {
        None => new_request(ctx, ReqKind::Send, ReqState::Complete(StatusCore::empty())),
        Some(id) => {
            new_request(ctx, ReqKind::Ssend { sync_id: id, dst: dst_world }, ReqState::Active)
        }
    })
}

/// Allocate the wire (kind, seq) for an eager send — and the ack id for
/// synchronous mode. Shared by [`isend_impl`] and the persistent start
/// path so the per-(src, context) send sequence stays monotone however
/// the send was issued.
fn send_wire_ids(ctx: &RankCtx, sync: bool) -> (MsgKind, u64, Option<u64>) {
    let mut st = ctx.state.borrow_mut();
    st.send_seq += 1;
    if sync {
        let id = st.next_sync_id;
        st.next_sync_id += 1;
        (MsgKind::EagerSync, id, Some(id))
    } else {
        (MsgKind::Eager, st.send_seq, None)
    }
}

/// `MPI_Isend` / `MPI_Issend`.
pub fn isend(
    buf: *const u8,
    count: usize,
    dt: DtId,
    dest: i32,
    tag: i32,
    comm: CommId,
    mode: SendMode,
) -> RC<ReqId> {
    with_ctx(|ctx| isend_impl(ctx, buf, count, dt, dest, tag, comm, mode))
}

/// `MPI_Send` / `MPI_Ssend`. Blocking sends take a **zero-allocation
/// fast path**: the packed payload is handed straight to the fabric
/// (with an inline backpressure spin that keeps this rank's own
/// progress running), and synchronous mode spins on the receiver's ack
/// — the request slab is never touched. The flat-baseline mode
/// (`MPI_ABI_FLAT_MATCH=1`) restores the seed's isend+wait path.
pub fn send(
    buf: *const u8,
    count: usize,
    dt: DtId,
    dest: i32,
    tag: i32,
    comm: CommId,
    mode: SendMode,
) -> RC<()> {
    with_ctx(|ctx| {
        if ctx.state.borrow().match_index.is_flat() {
            let rid = isend_impl(ctx, buf, count, dt, dest, tag, comm, mode)?;
            let s = wait_one(ctx, rid)?;
            if s.error != 0 {
                return Err(MpiError::new(s.error));
            }
            return Ok(());
        }
        send_fast(ctx, buf, count, dt, dest, tag, comm, mode)
    })
}

/// The blocking-send fast path. Validation and routing run first — every
/// `MPI_ERR_*` check fires exactly as on the slab path — then the
/// envelope goes to the fabric directly. Per-destination FIFO is
/// preserved: if deferred (backpressured) envelopes to this destination
/// exist, the spin lets the progress loop drain them ahead of us.
#[allow(clippy::too_many_arguments)]
fn send_fast(
    ctx: &RankCtx,
    buf: *const u8,
    count: usize,
    dt: DtId,
    dest: i32,
    tag: i32,
    comm: CommId,
    mode: SendMode,
) -> RC<()> {
    if dest == MPI_PROC_NULL {
        return Ok(());
    }
    let (dst_world, ctx_pt2pt) = route_send(ctx, dest, tag, comm)?;
    ctx.obs.sends_posted.set(ctx.obs.sends_posted.get() + 1);
    if rndv_switch(ctx, count, dt)? {
        let rndv = super::request::begin_rndv_send(ctx, dst_world, ctx_pt2pt, tag, buf, count, dt)?;
        // Spin until the stream drains (CTS received and every chunk
        // enqueued) — the rendezvous analogue of the Ssend ack spin. A
        // destination that dies (or a comm revoked) mid-stream would
        // spin forever: fail the send instead.
        while super::request::rndv_send_active(ctx, rndv) {
            if ctx.world.is_dead(dst_world) {
                ctx.state.borrow_mut().rndv_sends.remove(&rndv);
                ctx.obs.note_op_failed_proc();
                return Err(err!(MPI_ERR_PROC_FAILED));
            }
            if ctx.world.is_revoked(ctx_pt2pt) {
                ctx.state.borrow_mut().rndv_sends.remove(&rndv);
                return Err(err!(MPI_ERR_REVOKED));
            }
            progress(ctx);
            std::thread::yield_now();
        }
        return Ok(());
    }
    let payload = pack_payload(ctx, buf, count, dt)?;
    ctx.obs.eager_msgs.set(ctx.obs.eager_msgs.get() + 1);
    ctx.obs.eager_bytes.set(ctx.obs.eager_bytes.get() + payload.len() as u64);
    let (kind, seq, sync_id) = send_wire_ids(ctx, mode == SendMode::Sync);
    let mut env =
        Some(Envelope { src: ctx.rank as u32, context: ctx_pt2pt, tag, kind, seq, payload });
    loop {
        {
            let mut st = ctx.state.borrow_mut();
            if !st.pending_sends.contains_key(&dst_world) {
                match ctx.world.fabric.try_send(dst_world, env.take().unwrap()) {
                    Ok(()) => break,
                    Err(e) => env = Some(e),
                }
            }
        }
        // A destination that died with its ring full would leave us
        // spinning on backpressure forever.
        if ctx.world.is_dead(dst_world) {
            ctx.obs.note_op_failed_proc();
            return Err(err!(MPI_ERR_PROC_FAILED));
        }
        // Ring full (or deferred traffic ahead of us): progress our own
        // inbound so the peer can drain, then retry.
        progress(ctx);
        std::thread::yield_now();
    }
    if let Some(id) = sync_id {
        // Synchronous mode completes when the receiver matches the
        // message: spin on the ack, still without a request. A receiver
        // that dies before matching can never ack — fail, don't hang.
        loop {
            if ctx.state.borrow_mut().ssend_acks.remove(&id) {
                break;
            }
            if ctx.world.is_dead(dst_world) {
                ctx.obs.note_op_failed_proc();
                return Err(err!(MPI_ERR_PROC_FAILED));
            }
            if ctx.world.is_revoked(ctx_pt2pt) {
                return Err(err!(MPI_ERR_REVOKED));
            }
            progress(ctx);
            std::thread::yield_now();
        }
    }
    Ok(())
}

fn irecv_impl(
    ctx: &RankCtx,
    buf: *mut u8,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    comm: CommId,
) -> RC<ReqId> {
    if src == MPI_PROC_NULL {
        return Ok(new_request(ctx, ReqKind::Send, ReqState::Complete(StatusCore::empty())));
    }
    let (src_match, ctx_pt2pt) = route_recv(ctx, src, tag, comm)?;
    ctx.obs.recvs_posted.set(ctx.obs.recvs_posted.get() + 1);
    Ok(post_recv(ctx, buf as usize, count, dt, src_match, tag, ctx_pt2pt))
}

/// `MPI_Irecv`.
pub fn irecv(
    buf: *mut u8,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| irecv_impl(ctx, buf, count, dt, src, tag, comm))
}

/// `MPI_Recv`. Blocking receives take a **zero-allocation fast path**:
/// after full validation, the indexed unexpected queue is probed (O(1)
/// for exact matches) and the spin delivers straight from the index
/// into the user buffer — no request is ever allocated. Flat-baseline
/// mode (`MPI_ABI_FLAT_MATCH=1`) restores the seed's irecv+wait path.
pub fn recv(
    buf: *mut u8,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    comm: CommId,
) -> RC<StatusCore> {
    with_ctx(|ctx| {
        if ctx.state.borrow().match_index.is_flat() {
            let rid = irecv_impl(ctx, buf, count, dt, src, tag, comm)?;
            let mut s = wait_one(ctx, rid)?;
            if let Some(r) = super::comm::comm_rank_of_world(comm, s.source)? {
                s.source = r;
            }
            if s.error != 0 {
                return Err(MpiError::new(s.error));
            }
            return Ok(s);
        }
        recv_fast(ctx, buf, count, dt, src, tag, comm)
    })
}

/// The blocking-recv fast path. Taking from the unexpected index without
/// posting is safe because of the index invariant (no held message
/// matches an earlier-posted receive) plus the single-threaded rank
/// model: no receive can be posted while we spin, so this call is always
/// the newest — lowest-priority — receive. An arrival that matches an
/// earlier-posted receive is delivered to *it* by the progress loop, and
/// the spin simply keeps waiting for its own message.
fn recv_fast(
    ctx: &RankCtx,
    buf: *mut u8,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    comm: CommId,
) -> RC<StatusCore> {
    if src == MPI_PROC_NULL {
        return Ok(StatusCore::empty());
    }
    let (src_match, ctx_pt2pt) = route_recv(ctx, src, tag, comm)?;
    ctx.obs.recvs_posted.set(ctx.obs.recvs_posted.get() + 1);
    super::obs::trace(
        ctx,
        super::obs::TraceKind::Post,
        ctx_pt2pt,
        if tag == MPI_ANY_TAG { u32::MAX } else { tag as u32 },
    );
    loop {
        let hit = ctx.state.borrow_mut().match_index.take_unexpected(ctx_pt2pt, src_match, tag);
        if let Some(env) = hit {
            if let MsgKind::Rts { rndv, .. } = env.kind {
                // Rendezvous: open the stream inline (no request) and
                // spin until fully consumed into the user buffer.
                let src_world = env.src;
                super::request::begin_rndv_recv(ctx, None, &env, buf as usize, count, dt);
                loop {
                    if let Some(mut s) =
                        super::request::take_rndv_status(ctx, src_world, rndv)
                    {
                        if let Some(r) = super::comm::comm_rank_of_world(comm, s.source)? {
                            s.source = r;
                        }
                        if s.error != 0 {
                            return Err(MpiError::new(s.error));
                        }
                        return Ok(s);
                    }
                    progress(ctx);
                    std::thread::yield_now();
                }
            }
            let mut s = super::request::deliver_inline(ctx, env, buf as usize, count, dt);
            if let Some(r) = super::comm::comm_rank_of_world(comm, s.source)? {
                s.source = r;
            }
            if s.error != 0 {
                return Err(MpiError::new(s.error));
            }
            return Ok(s);
        }
        // ULFM failure checks run only after the take misses: a message
        // the peer sent before dying is still delivered.
        if ctx.world.is_revoked(ctx_pt2pt) {
            return Err(err!(MPI_ERR_REVOKED));
        }
        if ctx.world.any_dead() {
            if src_match == MPI_ANY_SOURCE {
                if super::comm::failure_pending_on_context(ctx, ctx_pt2pt) {
                    return Err(err!(MPI_ERR_PROC_FAILED_PENDING));
                }
            } else if ctx.world.is_dead(src_match as usize) {
                ctx.obs.note_op_failed_proc();
                return Err(err!(MPI_ERR_PROC_FAILED));
            }
        }
        progress(ctx);
        std::thread::yield_now();
    }
}

/// `MPI_Sendrecv`.
#[allow(clippy::too_many_arguments)]
pub fn sendrecv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    dest: i32,
    sendtag: i32,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    src: i32,
    recvtag: i32,
    comm: CommId,
) -> RC<StatusCore> {
    with_ctx(|ctx| {
        let r = irecv_impl(ctx, recvbuf, recvcount, recvtype, src, recvtag, comm)?;
        let s = isend_impl(ctx, sendbuf, sendcount, sendtype, dest, sendtag, comm, SendMode::Standard)?;
        // Either half completing in error (ULFM: dead peer, revoked comm)
        // fails the whole sendrecv — this is the detection point for
        // fault-tolerant halo exchanges.
        let ss = wait_one(ctx, s)?;
        if ss.error != 0 {
            return Err(MpiError::new(ss.error));
        }
        let mut st = wait_one(ctx, r)?;
        if st.error != 0 {
            return Err(MpiError::new(st.error));
        }
        if let Some(cr) = super::comm::comm_rank_of_world(comm, st.source)? {
            st.source = cr;
        }
        Ok(st)
    })
}

// ---------------------------------------------------------------------------
// Persistent point-to-point (MPI_Send_init / MPI_Recv_init / MPI_Start)
// ---------------------------------------------------------------------------

/// `MPI_Send_init` / `MPI_Ssend_init`: validate and comm-resolve the
/// arguments once, returning an **Inactive** persistent request.
/// `MPI_Start` re-packs the user buffer and enqueues the envelope — the
/// per-iteration path skips validation, routing, and request allocation.
pub fn send_init(
    buf: *const u8,
    count: usize,
    dt: DtId,
    dest: i32,
    tag: i32,
    comm: CommId,
    mode: SendMode,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let sync = mode == SendMode::Sync;
        if dest == MPI_PROC_NULL {
            return Ok(new_persistent(
                ctx,
                ReqKind::Send,
                PersistSpec::Send {
                    buf: buf as usize,
                    count,
                    dt,
                    dest_world: None,
                    tag,
                    context: 0,
                    sync,
                },
            ));
        }
        let (dst_world, ctx_pt2pt) = route_send(ctx, dest, tag, comm)?;
        Ok(new_persistent(
            ctx,
            ReqKind::Send,
            PersistSpec::Send {
                buf: buf as usize,
                count,
                dt,
                dest_world: Some(dst_world),
                tag,
                context: ctx_pt2pt,
                sync,
            },
        ))
    })
}

/// `MPI_Recv_init`: the receive-side persistent init. Each `MPI_Start`
/// re-posts the receive into the matching engine.
pub fn recv_init(
    buf: *mut u8,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        if src == MPI_PROC_NULL {
            return Ok(new_persistent(
                ctx,
                ReqKind::Send,
                PersistSpec::Recv {
                    buf: buf as usize,
                    count,
                    dt,
                    src: MPI_PROC_NULL,
                    tag,
                    context: 0,
                },
            ));
        }
        let (src_match, ctx_pt2pt) = route_recv(ctx, src, tag, comm)?;
        // The armed kind is installed by each start (repost_recv); until
        // then the spec is the single source of truth.
        Ok(new_persistent(
            ctx,
            ReqKind::Send,
            PersistSpec::Recv {
                buf: buf as usize,
                count,
                dt,
                src: src_match,
                tag,
                context: ctx_pt2pt,
            },
        ))
    })
}

/// `MPI_Start`: re-arm one Inactive persistent request. Starting a
/// request that is active (or was never created persistent) is an error.
pub fn start(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| start_impl(ctx, rid))
}

/// `MPI_Startall`: start a batch of persistent requests, in order.
pub fn startall(rids: &[ReqId]) -> RC<()> {
    with_ctx(|ctx| {
        for &rid in rids {
            start_impl(ctx, rid)?;
        }
        Ok(())
    })
}

fn start_impl(ctx: &RankCtx, rid: ReqId) -> RC<()> {
    let spec = {
        let t = ctx.tables.borrow();
        let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
        match (&req.persist, &req.state) {
            (Some(spec), ReqState::Inactive) => *spec,
            // Start on an active (or complete-but-uncollected) request,
            // or on a nonpersistent request, is erroneous.
            _ => return Err(err!(MPI_ERR_REQUEST)),
        }
    };
    match spec {
        PersistSpec::Send { buf, count, dt, dest_world, tag, context, sync } => {
            let Some(dst_world) = dest_world else {
                arm_as(ctx, rid, ReqKind::Send, ReqState::Complete(StatusCore::empty()));
                return Ok(());
            };
            ctx.obs.sends_posted.set(ctx.obs.sends_posted.get() + 1);
            if rndv_switch(ctx, count, dt)? {
                let rndv = super::request::begin_rndv_send(
                    ctx,
                    dst_world,
                    context,
                    tag,
                    buf as *const u8,
                    count,
                    dt,
                )?;
                arm_as(ctx, rid, ReqKind::RndvSend { rndv }, ReqState::Active);
                return Ok(());
            }
            let payload = pack_payload(ctx, buf as *const u8, count, dt)?;
            ctx.obs.eager_msgs.set(ctx.obs.eager_msgs.get() + 1);
            ctx.obs.eager_bytes.set(ctx.obs.eager_bytes.get() + payload.len() as u64);
            let (msg_kind, seq, sync_id) = send_wire_ids(ctx, sync);
            let (req_kind, state) = match sync_id {
                Some(id) => (ReqKind::Ssend { sync_id: id, dst: dst_world }, ReqState::Active),
                None => (ReqKind::Send, ReqState::Complete(StatusCore::empty())),
            };
            let env = Envelope {
                src: ctx.rank as u32,
                context,
                tag,
                kind: msg_kind,
                seq,
                payload,
            };
            enqueue_send(ctx, dst_world, env);
            arm_as(ctx, rid, req_kind, state);
            Ok(())
        }
        PersistSpec::Recv { buf, count, dt, src, tag, context } => {
            if src == MPI_PROC_NULL {
                arm_as(ctx, rid, ReqKind::Send, ReqState::Complete(StatusCore::empty()));
                return Ok(());
            }
            ctx.obs.recvs_posted.set(ctx.obs.recvs_posted.get() + 1);
            super::request::repost_recv(ctx, rid, buf, count, dt, src, tag, context);
            Ok(())
        }
        PersistSpec::Coll => super::collectives::sched::start_sched(ctx, rid),
    }
}

/// Flip a persistent request into its armed form.
fn arm_as(ctx: &RankCtx, rid: ReqId, kind: ReqKind, state: ReqState) {
    if let Some(req) = ctx.tables.borrow_mut().reqs.get_mut(rid.0) {
        req.kind = kind;
        req.state = state;
    }
}

/// Whether `rid` is a persistent request. ABI shims use this to keep the
/// user's handle valid across wait/test (persistent handles survive
/// completion; nonpersistent handles become `MPI_REQUEST_NULL`).
pub fn request_is_persistent(rid: ReqId) -> bool {
    super::world::try_ctx(|ctx| {
        ctx.map(|c| super::request::is_persistent(c, rid)).unwrap_or(false)
    })
}

/// `MPI_Probe`: blocking; returns the matched message's status without
/// receiving it.
pub fn probe(src: i32, tag: i32, comm: CommId) -> RC<StatusCore> {
    loop {
        if let Some(s) = iprobe(src, tag, comm)? {
            return Ok(s);
        }
        std::thread::yield_now();
    }
}

/// `MPI_Iprobe`.
pub fn iprobe(src: i32, tag: i32, comm: CommId) -> RC<Option<StatusCore>> {
    if src == MPI_PROC_NULL {
        // MPI 3.0 §3.8: probe on MPI_PROC_NULL matches immediately with
        // an empty status — same short-circuit as every receive path.
        return Ok(Some(StatusCore::empty()));
    }
    let found = with_ctx(|ctx| {
        // Same validation/routing as every receive path (so probe with
        // an invalid tag errors instead of spinning forever).
        let (src_match, ctx_pt2pt) = route_recv(ctx, src, tag, comm)?;
        progress(ctx);
        let st = ctx.state.borrow();
        // Earliest-arrived match, straight from the unexpected index.
        if let Some(env) = st.match_index.peek_unexpected(ctx_pt2pt, src_match, tag) {
            // `data_len`, not payload length: a probed RTS must report
            // the announced message size, not its empty control payload.
            return Ok(Some(StatusCore::success(env.src as i32, env.tag, env.data_len())));
        }
        drop(st);
        // No buffered match: a dead concrete source (or an
        // unacknowledged failure under a wildcard) means none can come —
        // fail so the blocking `probe` loop terminates.
        if ctx.world.any_dead() {
            if src_match == MPI_ANY_SOURCE {
                if super::comm::failure_pending_on_context(ctx, ctx_pt2pt) {
                    return Err(err!(MPI_ERR_PROC_FAILED_PENDING));
                }
            } else if ctx.world.is_dead(src_match as usize) {
                ctx.obs.note_op_failed_proc();
                return Err(err!(MPI_ERR_PROC_FAILED));
            }
        }
        Ok(None)
    })?;
    match found {
        Some(mut s) => {
            if let Some(cr) = super::comm::comm_rank_of_world(comm, s.source)? {
                s.source = cr;
            }
            Ok(Some(s))
        }
        None => Ok(None),
    }
}

// ---------------------------------------------------------------------------
// Completion
// ---------------------------------------------------------------------------

/// `MPI_Wait`. A request completed *in error* (ULFM: its peer died or
/// its comm was revoked) is retired like any completed request, but the
/// failure surfaces as this call's return code — MPI_Wait on a single
/// request reports operation errors directly, unlike waitall's
/// error-in-status convention.
pub fn wait(rid: ReqId) -> RC<StatusCore> {
    with_ctx(|ctx| {
        let s = wait_one(ctx, rid)?;
        if s.error != 0 {
            return Err(crate::core::MpiError::new(s.error));
        }
        Ok(s)
    })
}

/// `MPI_Test` (same completed-in-error convention as [`wait`]).
pub fn test(rid: ReqId) -> RC<Option<StatusCore>> {
    with_ctx(|ctx| match test_one(ctx, rid)? {
        Some(s) if s.error != 0 => Err(crate::core::MpiError::new(s.error)),
        other => Ok(other),
    })
}

/// `MPI_Waitall`.
pub fn waitall(rids: &[ReqId]) -> RC<Vec<StatusCore>> {
    with_ctx(|ctx| {
        let mut done: Vec<Option<StatusCore>> = vec![None; rids.len()];
        loop {
            // One progress cycle per sweep (not per request): draining the
            // fabric once lets the whole window match in a single pass.
            progress(ctx);
            let mut all = true;
            for (i, &rid) in rids.iter().enumerate() {
                if done[i].is_none() {
                    match super::request::finish_if_done(ctx, rid)? {
                        Some(s) => {
                            super::request::retire(ctx, rid);
                            done[i] = Some(s);
                        }
                        None => all = false,
                    }
                }
            }
            if all {
                return Ok(done.into_iter().map(|s| s.unwrap()).collect());
            }
            std::thread::yield_now();
        }
    })
}

/// `MPI_Testall`: `Some(statuses)` iff all complete (and then all freed).
pub fn testall(rids: &[ReqId]) -> RC<Option<Vec<StatusCore>>> {
    with_ctx(|ctx| {
        progress(ctx);
        let mut out = Vec::with_capacity(rids.len());
        for &rid in rids {
            match super::request::finish_if_done(ctx, rid)? {
                Some(s) => out.push(s),
                None => return Ok(None),
            }
        }
        for &rid in rids {
            super::request::retire(ctx, rid);
        }
        Ok(Some(out))
    })
}

/// `MPI_Waitany` → `Some((index, status))`, or `None` when every request
/// in the list is an inactive persistent one (MPI 3.0 §3.7.5: waitany
/// ignores inactive handles; with no active handle it returns
/// `MPI_UNDEFINED` + empty status, which the ABI shims synthesize).
pub fn waitany(rids: &[ReqId]) -> RC<Option<(usize, StatusCore)>> {
    with_ctx(|ctx| loop {
        progress(ctx);
        let mut any_active = false;
        for (i, &rid) in rids.iter().enumerate() {
            if super::request::is_inactive(ctx, rid)? {
                continue;
            }
            any_active = true;
            if let Some(s) = super::request::finish_if_done(ctx, rid)? {
                super::request::retire(ctx, rid);
                return Ok(Some((i, s)));
            }
        }
        if !any_active {
            return Ok(None);
        }
        std::thread::yield_now();
    })
}

/// Outcome of [`testany`], mirroring MPI 3.0 §3.7.5's three cases.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TestAnyOutcome {
    /// An active request completed: its index and status.
    Completed(usize, StatusCore),
    /// Every request in the list is inactive (or the list is empty):
    /// flag=true with `MPI_UNDEFINED` and an empty status at the ABI.
    NoneActive,
    /// Active requests exist but none has completed yet (flag=false).
    Pending,
}

/// `MPI_Testany`. Inactive persistent requests are ignored, as in
/// [`waitany`]; the outcome distinguishes "all inactive" from "none
/// complete yet" so ABI shims can report the §3.7.5 flag correctly.
pub fn testany(rids: &[ReqId]) -> RC<TestAnyOutcome> {
    with_ctx(|ctx| {
        progress(ctx);
        let mut any_active = false;
        for (i, &rid) in rids.iter().enumerate() {
            if super::request::is_inactive(ctx, rid)? {
                continue;
            }
            any_active = true;
            if let Some(s) = super::request::finish_if_done(ctx, rid)? {
                super::request::retire(ctx, rid);
                return Ok(TestAnyOutcome::Completed(i, s));
            }
        }
        Ok(if any_active { TestAnyOutcome::Pending } else { TestAnyOutcome::NoneActive })
    })
}

/// `MPI_Waitsome`: block until at least one *active* request completes,
/// then return every request complete at that moment. `None` = the list
/// has no active request (all null at the ABI, or inactive persistent):
/// the ABI reports `outcount = MPI_UNDEFINED` (MPI 3.0 §3.7.5).
pub fn waitsome(rids: &[ReqId]) -> RC<Option<Vec<(usize, StatusCore)>>> {
    with_ctx(|ctx| loop {
        progress(ctx);
        let mut any_active = false;
        let mut done = Vec::new();
        for (i, &rid) in rids.iter().enumerate() {
            if super::request::is_inactive(ctx, rid)? {
                continue;
            }
            any_active = true;
            if let Some(s) = super::request::finish_if_done(ctx, rid)? {
                super::request::retire(ctx, rid);
                done.push((i, s));
            }
        }
        if !any_active {
            return Ok(None);
        }
        if !done.is_empty() {
            return Ok(Some(done));
        }
        std::thread::yield_now();
    })
}

/// `MPI_Testsome`: like [`waitsome`] without blocking — `Some(vec)` may
/// be empty when active requests exist but none has completed.
pub fn testsome(rids: &[ReqId]) -> RC<Option<Vec<(usize, StatusCore)>>> {
    with_ctx(|ctx| {
        progress(ctx);
        let mut any_active = false;
        let mut done = Vec::new();
        for (i, &rid) in rids.iter().enumerate() {
            if super::request::is_inactive(ctx, rid)? {
                continue;
            }
            any_active = true;
            if let Some(s) = super::request::finish_if_done(ctx, rid)? {
                super::request::retire(ctx, rid);
                done.push((i, s));
            }
        }
        if !any_active {
            return Ok(None);
        }
        Ok(Some(done))
    })
}

/// `MPI_Get_count`. A true count above `i32::MAX` is not representable
/// in the narrow `int` signature, so it reports `MPI_UNDEFINED` (MPI-4.1
/// §3.2.5) — never a silently truncated value; `MPI_Get_count_c`
/// ([`get_count_c`]) is the lossless query.
pub fn get_count(status: &StatusCore, dt: DtId) -> RC<i32> {
    let size = super::datatype::type_size(dt)?;
    if size == 0 {
        return Ok(0);
    }
    if status.count_bytes % size as u64 != 0 {
        return Ok(MPI_UNDEFINED);
    }
    let n = status.count_bytes / size as u64;
    if n > i32::MAX as u64 {
        return Ok(MPI_UNDEFINED);
    }
    Ok(n as i32)
}

/// `MPI_Get_count_c`: the embiggened count query — same divisibility
/// rule as [`get_count`], full `MPI_Count` range.
pub fn get_count_c(status: &StatusCore, dt: DtId) -> RC<i64> {
    let size = super::datatype::type_size(dt)?;
    if size == 0 {
        return Ok(0);
    }
    if status.count_bytes % size as u64 != 0 {
        return Ok(MPI_UNDEFINED as i64);
    }
    Ok((status.count_bytes / size as u64) as i64)
}

/// `MPI_Get_elements`: the number of *basic* elements received — unlike
/// [`get_count`] it resolves partial items of a derived datatype down to
/// their leaves (pair types count as two elements). `MPI_UNDEFINED` only
/// when the byte count splits a basic element.
pub fn get_elements(status: &StatusCore, dt: DtId) -> RC<i32> {
    let elems = get_elements_c(status, dt)?;
    if elems == MPI_UNDEFINED as i64 || elems > i32::MAX as i64 {
        // Above the narrow signature's range: MPI_UNDEFINED, same rule
        // as `MPI_Get_count` (use `MPI_Get_elements_c` instead).
        return Ok(MPI_UNDEFINED);
    }
    Ok(elems as i32)
}

/// `MPI_Get_elements_c`: the embiggened basic-element query.
pub fn get_elements_c(status: &StatusCore, dt: DtId) -> RC<i64> {
    let leaves = super::datatype::leaf_sizes(dt)?;
    let item_size: usize = leaves.iter().sum();
    let bytes = status.count_bytes;
    if item_size == 0 || leaves.is_empty() {
        return Ok(0);
    }
    let full_items = bytes / item_size as u64;
    let mut elems = full_items * leaves.len() as u64;
    let mut rem = (bytes % item_size as u64) as usize;
    for &l in &leaves {
        if rem == 0 {
            break;
        }
        if rem < l {
            return Ok(MPI_UNDEFINED as i64); // a basic element was split
        }
        rem -= l;
        elems += 1;
    }
    Ok(elems as i64)
}

// ---------------------------------------------------------------------------
// Communicator creation (collective)
// ---------------------------------------------------------------------------

/// `MPI_Comm_dup`: same group, fresh context ids, attributes copied per
/// their copy callbacks.
pub fn comm_dup(comm: CommId) -> RC<CommId> {
    let (members, my_rank, _, _) = comm_snapshot(comm)?;
    // Rank 0 of the comm allocates the context pair and broadcasts it.
    let mut ctx_pair = [0u32; 2];
    if my_rank == 0 {
        let (p, c) = with_ctx(|ctx| Ok(ctx.world.alloc_context_pair()))?;
        ctx_pair = [p, c];
    }
    let mut bytes = [0u8; 8];
    bytes[..4].copy_from_slice(&ctx_pair[0].to_le_bytes());
    bytes[4..].copy_from_slice(&ctx_pair[1].to_le_bytes());
    super::collectives::bcast_bytes(&mut bytes, 0, comm)?;
    let p = u32::from_le_bytes(bytes[..4].try_into().unwrap());
    let c = u32::from_le_bytes(bytes[4..].try_into().unwrap());
    let new = super::comm::insert_comm(members, my_rank, p, c)?;
    super::attr::copy_attrs_for_dup(comm, new)?;
    // Dup'd comms inherit the error handler.
    let errh = super::comm::comm_get_errhandler(comm)?;
    super::comm::comm_set_errhandler(new, errh)?;
    Ok(new)
}

/// `MPI_Comm_split`. Returns `None` for `MPI_UNDEFINED` color.
pub fn comm_split(comm: CommId, color: i32, key: i32) -> RC<Option<CommId>> {
    let (members, my_rank, _, _) = comm_snapshot(comm)?;
    let size = members.len();
    // Gather (color, key) at comm rank 0.
    let mine = [color, key];
    let mut all: Vec<i32> = vec![0; 2 * size];
    super::collectives::gather_bytes(as_bytes(&mine), as_bytes_mut(&mut all), 0, comm)?;
    // Rank 0 computes each member's (new_rank, ctxp, ctxc, world members…)
    // and scatters the variable-size results.
    let mut results: Vec<Vec<u8>> = Vec::new();
    if my_rank == 0 {
        results = split_assignments(&all, &members)?;
    }
    let my_blob = super::collectives::scatter_var_bytes(&results, 0, comm)?;
    decode_split_result(&my_blob)
}

/// `MPI_Comm_split_type`. `MPI_COMM_TYPE_SHARED` groups ranks that
/// share memory — our ranks are threads of one process, so *every*
/// member shares memory and the split is total (color 0, key-ordered).
/// `MPI_UNDEFINED` ranks still participate in the collective exchange
/// but get no communicator. Any other split type is `MPI_ERR_ARG`.
pub fn comm_split_type(comm: CommId, split_type: i32, key: i32) -> RC<Option<CommId>> {
    let color = match split_type {
        crate::abi::constants::MPI_COMM_TYPE_SHARED => 0,
        MPI_UNDEFINED => MPI_UNDEFINED,
        _ => return Err(err!(MPI_ERR_ARG)),
    };
    comm_split(comm, color, key)
}

fn split_assignments(colorkeys: &[i32], parent_members: &[usize]) -> RC<Vec<Vec<u8>>> {
    let size = parent_members.len();
    let mut colors: Vec<i32> = Vec::new();
    for r in 0..size {
        let c = colorkeys[2 * r];
        if c != MPI_UNDEFINED && !colors.contains(&c) {
            colors.push(c);
        }
    }
    colors.sort_unstable();
    let mut blobs: Vec<Vec<u8>> = vec![Vec::new(); size];
    for &c in &colors {
        let mut group: Vec<usize> = (0..size).filter(|&r| colorkeys[2 * r] == c).collect();
        // Order by (key, old rank).
        group.sort_by_key(|&r| (colorkeys[2 * r + 1], r));
        let (ctxp, ctxc) = with_ctx(|ctx| Ok(ctx.world.alloc_context_pair()))?;
        for (new_rank, &old_rank) in group.iter().enumerate() {
            let mut b = Vec::with_capacity(16 + 4 * group.len());
            b.extend_from_slice(&(new_rank as u32).to_le_bytes());
            b.extend_from_slice(&ctxp.to_le_bytes());
            b.extend_from_slice(&ctxc.to_le_bytes());
            b.extend_from_slice(&(group.len() as u32).to_le_bytes());
            for &r in &group {
                // Store *world* ranks so members need no further translation.
                b.extend_from_slice(&(parent_members[r] as u32).to_le_bytes());
            }
            blobs[old_rank] = b;
        }
    }
    Ok(blobs)
}

fn decode_split_result(blob: &[u8]) -> RC<Option<CommId>> {
    if blob.is_empty() {
        return Ok(None); // MPI_UNDEFINED color
    }
    let rd = |i: usize| u32::from_le_bytes(blob[4 * i..4 * i + 4].try_into().unwrap());
    let new_rank = rd(0) as usize;
    let ctxp = rd(1);
    let ctxc = rd(2);
    let n = rd(3) as usize;
    let world_members: Vec<usize> = (0..n).map(|i| rd(4 + i) as usize).collect();
    Ok(Some(super::comm::insert_comm(world_members, new_rank, ctxp, ctxc)?))
}

/// `MPI_Comm_create` from a group (collective over `comm`).
pub fn comm_create(comm: CommId, group: super::GroupId) -> RC<Option<CommId>> {
    let (members, my_rank, _, _) = comm_snapshot(comm)?;
    let _ = members;
    // Rank 0 allocates a context pair for the new comm; everyone gets it.
    let mut ctx_pair = [0u32; 2];
    if my_rank == 0 {
        let (p, c) = with_ctx(|ctx| Ok(ctx.world.alloc_context_pair()))?;
        ctx_pair = [p, c];
    }
    let mut bytes = [0u8; 8];
    bytes[..4].copy_from_slice(&ctx_pair[0].to_le_bytes());
    bytes[4..].copy_from_slice(&ctx_pair[1].to_le_bytes());
    super::collectives::bcast_bytes(&mut bytes, 0, comm)?;
    let p = u32::from_le_bytes(bytes[..4].try_into().unwrap());
    let c = u32::from_le_bytes(bytes[4..].try_into().unwrap());
    let (g_members, my_world) = with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let g = t.groups.get(group.0).ok_or(err!(MPI_ERR_GROUP))?;
        Ok((g.members.clone(), ctx.rank))
    })?;
    match g_members.iter().position(|&m| m == my_world) {
        Some(new_rank) => Ok(Some(super::comm::insert_comm(g_members, new_rank, p, c)?)),
        None => Ok(None),
    }
}

// ---------------------------------------------------------------------------
// ULFM fault tolerance (MPI_Comm_revoke / shrink / agree)
// ---------------------------------------------------------------------------

/// Derive a bootstrap-plane wire tag for a ULFM recovery exchange on the
/// comm whose pt2pt plane is `ctx_plane`. Same construction discipline
/// as [`super::session::pset_tag`]: folded strictly into the legal tag
/// range; `salt` separates the agree and shrink protocols so concurrent
/// recovery steps on one comm can never cross-wire.
fn ulfm_tag(ctx_plane: u32, salt: u32) -> i32 {
    ((ctx_plane.wrapping_mul(0x9E37_79B9).wrapping_add(salt)) & 0x007F_FFFF) as i32
}

/// `MPI_Comm_revoke` (ULFM): permanently poison both of the comm's
/// context planes. Revocation is job-global state — every member's
/// in-flight and future operations on this comm fail with
/// `MPI_ERR_REVOKED` (no new message is required to propagate it, which
/// is exactly the guarantee ULFM revocation exists to give). A second
/// revoke of the same comm is a no-op success.
pub fn comm_revoke(comm: CommId) -> RC<()> {
    let (_, _, ctxp, ctxc) = comm_snapshot(comm)?;
    with_ctx(|ctx| {
        let newly_p = ctx.world.revoke_context(ctxp);
        let newly_c = ctx.world.revoke_context(ctxc);
        if newly_p || newly_c {
            // Counts *comms*, once, even though two planes were poisoned.
            ctx.world.obs.note_comm_revoked();
        }
        Ok(())
    })
}

/// ULFM helper: whether the comm has been revoked (by any member).
pub fn comm_is_revoked(comm: CommId) -> RC<bool> {
    let (_, _, ctxp, _) = comm_snapshot(comm)?;
    with_ctx(|ctx| Ok(ctx.world.is_revoked(ctxp)))
}

/// `MPI_Comm_ack_failed` (ULFM): acknowledge up to `num_to_ack` known
/// failures on the comm, returning the number acknowledged. Once every
/// known failure is acknowledged, wildcard receives on the comm stop
/// raising `MPI_ERR_PROC_FAILED_PENDING`.
pub fn comm_ack_failed(comm: CommId, num_to_ack: i32) -> RC<i32> {
    super::comm::comm_ack_failed(comm, num_to_ack)
}

/// `MPI_Comm_agree` (ULFM): fault-tolerant agreement — returns the
/// bitwise AND of `flag` over the comm's *surviving* members. Runs over
/// the hidden bootstrap communicator's planes (which are never revoked),
/// so it works on a revoked comm: revoke → agree → shrink is the ULFM
/// recovery sequence. The coordinator is the lowest-ranked survivor;
/// contributions from members that die mid-protocol are skipped.
pub fn comm_agree(comm: CommId, flag: i32) -> RC<i32> {
    let (members, my_rank, ctxp, _) = comm_snapshot(comm)?;
    let byte = super::datatype::builtin_id_of_abi(crate::abi::datatypes::MPI_BYTE)
        .ok_or(err!(MPI_ERR_INTERN))?;
    let wire_tag = ulfm_tag(ctxp, 1);
    let dead: Vec<bool> =
        with_ctx(|ctx| Ok(members.iter().map(|&m| ctx.world.is_dead(m)).collect()))?;
    let root = dead.iter().position(|&d| !d).ok_or(err!(MPI_ERR_PROC_FAILED))?;
    let mut agreed = flag;
    if my_rank == root {
        for (r, &m) in members.iter().enumerate() {
            if r == root || dead[r] {
                continue;
            }
            let mut b = [0u8; 4];
            // The bootstrap comm spans the world in world-rank order, so
            // a member's world rank *is* its bootstrap rank.
            match recv(b.as_mut_ptr(), 4, byte, m as i32, wire_tag, super::reserved::COMM_BOOTSTRAP)
            {
                Ok(_) => agreed &= i32::from_le_bytes(b),
                Err(e) if e.class == crate::abi::errors::MPI_ERR_PROC_FAILED => {}
                Err(e) => return Err(e),
            }
        }
        let out = agreed.to_le_bytes();
        for (r, &m) in members.iter().enumerate() {
            if r == root || dead[r] {
                continue;
            }
            match send(
                out.as_ptr(),
                4,
                byte,
                m as i32,
                wire_tag,
                super::reserved::COMM_BOOTSTRAP,
                SendMode::Standard,
            ) {
                Ok(()) => {}
                Err(e) if e.class == crate::abi::errors::MPI_ERR_PROC_FAILED => {}
                Err(e) => return Err(e),
            }
        }
    } else {
        let b = flag.to_le_bytes();
        send(
            b.as_ptr(),
            4,
            byte,
            members[root] as i32,
            wire_tag,
            super::reserved::COMM_BOOTSTRAP,
            SendMode::Standard,
        )?;
        let mut rb = [0u8; 4];
        recv(rb.as_mut_ptr(), 4, byte, members[root] as i32, wire_tag, super::reserved::COMM_BOOTSTRAP)?;
        agreed = i32::from_le_bytes(rb);
    }
    Ok(agreed)
}

/// `MPI_Comm_shrink` (ULFM): build a fresh communicator over the comm's
/// surviving members — fresh context planes, survivor-ordered ranks.
/// Like [`comm_agree`] this bootstraps over the hidden bootstrap
/// communicator, so it works on a revoked (or failure-poisoned) parent.
/// The lowest-ranked survivor allocates the plane pair and distributes
/// `[ctxp, ctxc, n, survivor world ranks…]`; every member installs the
/// *received* survivor list, so all members agree on the new comm's
/// membership even if their own failure views raced.
pub fn comm_shrink(comm: CommId) -> RC<CommId> {
    let (members, my_rank, ctxp, _) = comm_snapshot(comm)?;
    let byte = super::datatype::builtin_id_of_abi(crate::abi::datatypes::MPI_BYTE)
        .ok_or(err!(MPI_ERR_INTERN))?;
    let wire_tag = ulfm_tag(ctxp, 2);
    let my_world = members[my_rank];
    let survivors: Vec<usize> = with_ctx(|ctx| {
        Ok(members.iter().copied().filter(|&m| !ctx.world.is_dead(m)).collect())
    })?;
    let new_rank = survivors
        .iter()
        .position(|&m| m == my_world)
        .ok_or(err!(MPI_ERR_PROC_FAILED))?;
    if new_rank == 0 {
        let (p, c) = with_ctx(|ctx| Ok(ctx.world.alloc_context_pair()))?;
        let mut blob = Vec::with_capacity(12 + 4 * survivors.len());
        blob.extend_from_slice(&p.to_le_bytes());
        blob.extend_from_slice(&c.to_le_bytes());
        blob.extend_from_slice(&(survivors.len() as u32).to_le_bytes());
        for &m in &survivors {
            blob.extend_from_slice(&(m as u32).to_le_bytes());
        }
        for &m in &survivors[1..] {
            match send(
                blob.as_ptr(),
                blob.len(),
                byte,
                m as i32,
                wire_tag,
                super::reserved::COMM_BOOTSTRAP,
                SendMode::Standard,
            ) {
                Ok(()) => {}
                Err(e) if e.class == crate::abi::errors::MPI_ERR_PROC_FAILED => {}
                Err(e) => return Err(e),
            }
        }
        super::comm::insert_comm(survivors, 0, p, c)
    } else {
        // Post capacity for the full parent membership: the root's
        // survivor list can only be our view or smaller.
        let mut blob = vec![0u8; 12 + 4 * members.len()];
        recv(
            blob.as_mut_ptr(),
            blob.len(),
            byte,
            survivors[0] as i32,
            wire_tag,
            super::reserved::COMM_BOOTSTRAP,
        )?;
        let rd = |i: usize| u32::from_le_bytes(blob[4 * i..4 * i + 4].try_into().unwrap());
        let p = rd(0);
        let c = rd(1);
        let n = rd(2) as usize;
        let got: Vec<usize> = (0..n).map(|i| rd(3 + i) as usize).collect();
        let rank = got
            .iter()
            .position(|&m| m == my_world)
            .ok_or(err!(MPI_ERR_PROC_FAILED))?;
        super::comm::insert_comm(got, rank, p, c)
    }
}

// Helpers for viewing i32 slices as bytes (little-endian host).
pub(crate) fn as_bytes(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

pub(crate) fn as_bytes_mut(v: &mut [i32]) -> &mut [u8] {
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, std::mem::size_of_val(v)) }
}
