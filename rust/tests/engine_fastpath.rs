//! Engine-level coverage of the zero-alloc pt2pt fast paths: blocking
//! send/recv bypass the request slab (indexed mode), yet must be
//! **observably identical** to the slab (isend/irecv) path — FIFO order
//! under mixed traffic, identical validation errors, identical results
//! in flat-baseline mode.

use mpi_abi::abi::errors as ec;
use mpi_abi::core::engine::{self, SendMode};
use mpi_abi::core::reserved::COMM_WORLD;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::core::{datatype, engine::wait};
use mpi_abi::launcher::{run_job_ok, JobSpec};

fn dt_i32() -> mpi_abi::core::DtId {
    datatype::builtin_id_of_abi(mpi_abi::abi::datatypes::MPI_INT32_T).unwrap()
}

/// Mixed blocking (fast-path) and nonblocking (slab-path) traffic on one
/// (context, src, tag): the i-th receive — whichever path — must get the
/// i-th sent value. Runs both transports and both matching modes.
#[test]
fn mixed_blocking_nonblocking_fifo() {
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        for flat in [false, true] {
            let spec = JobSpec::new(2).with_transport(transport).with_flat_match(flat);
            let out = run_job_ok(spec, |rank| {
                engine::init().unwrap();
                let dt = dt_i32();
                let mut got = [0i32; 4];
                if rank == 0 {
                    // isend, blocking send, isend, blocking send — FIFO.
                    let vals = [10i32, 11, 12, 13];
                    let r0 = engine::isend(
                        vals[0..1].as_ptr() as *const u8,
                        1,
                        dt,
                        1,
                        5,
                        COMM_WORLD,
                        SendMode::Standard,
                    )
                    .unwrap();
                    engine::send(
                        vals[1..2].as_ptr() as *const u8,
                        1,
                        dt,
                        1,
                        5,
                        COMM_WORLD,
                        SendMode::Standard,
                    )
                    .unwrap();
                    let r2 = engine::isend(
                        vals[2..3].as_ptr() as *const u8,
                        1,
                        dt,
                        1,
                        5,
                        COMM_WORLD,
                        SendMode::Sync,
                    )
                    .unwrap();
                    engine::send(
                        vals[3..4].as_ptr() as *const u8,
                        1,
                        dt,
                        1,
                        5,
                        COMM_WORLD,
                        SendMode::Standard,
                    )
                    .unwrap();
                    wait(r0).unwrap();
                    wait(r2).unwrap();
                } else {
                    // irecv, blocking recv, irecv, blocking recv — the
                    // posted-order × arrival-order contract must hold
                    // across the two implementation paths.
                    let r0 = engine::irecv(
                        got[0..1].as_mut_ptr() as *mut u8,
                        1,
                        dt,
                        0,
                        5,
                        COMM_WORLD,
                    )
                    .unwrap();
                    let s1 =
                        engine::recv(got[1..2].as_mut_ptr() as *mut u8, 1, dt, 0, 5, COMM_WORLD)
                            .unwrap();
                    let r2 = engine::irecv(
                        got[2..3].as_mut_ptr() as *mut u8,
                        1,
                        dt,
                        0,
                        5,
                        COMM_WORLD,
                    )
                    .unwrap();
                    let s3 =
                        engine::recv(got[3..4].as_mut_ptr() as *mut u8, 1, dt, 0, 5, COMM_WORLD)
                            .unwrap();
                    let st0 = wait(r0).unwrap();
                    let st2 = wait(r2).unwrap();
                    assert_eq!(st0.source, 0);
                    assert_eq!(st2.source, 0);
                    assert_eq!(s1.source, 0);
                    assert_eq!(s1.tag, 5);
                    assert_eq!(s3.tag, 5);
                }
                engine::finalize().unwrap();
                got
            });
            // Receives were issued in slot order (irecv, recv, irecv,
            // recv), so FIFO demands 10,11,12,13 land in slot order.
            assert_eq!(
                out[1],
                [10, 11, 12, 13],
                "FIFO broken (transport {transport:?}, flat {flat})"
            );
        }
    }
}

/// Validation fires before the fast path short-circuits: erroneous
/// arguments produce the same `MPI_ERR_*` classes on the fast path as
/// on the slab path — even when a matching message is already waiting.
#[test]
fn validation_before_fast_path() {
    for flat in [false, true] {
        run_job_ok(JobSpec::new(2).with_flat_match(flat), |rank| {
            engine::init().unwrap();
            let dt = dt_i32();
            let v = [1i32];
            let mut buf = [0i32];
            // Bad tag on send.
            let e = engine::send(
                v.as_ptr() as *const u8,
                1,
                dt,
                (rank as i32 + 1) % 2,
                -3,
                COMM_WORLD,
                SendMode::Standard,
            )
            .unwrap_err();
            assert_eq!(e.class, ec::MPI_ERR_TAG, "flat={flat}");
            // Bad rank on send.
            let e = engine::send(
                v.as_ptr() as *const u8,
                1,
                dt,
                99,
                3,
                COMM_WORLD,
                SendMode::Standard,
            )
            .unwrap_err();
            assert_eq!(e.class, ec::MPI_ERR_RANK, "flat={flat}");
            // Bad rank on recv.
            let e = engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 99, 3, COMM_WORLD)
                .unwrap_err();
            assert_eq!(e.class, ec::MPI_ERR_RANK, "flat={flat}");
            // Bad (non-wildcard) tag on recv.
            let e = engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 0, -7, COMM_WORLD)
                .unwrap_err();
            assert_eq!(e.class, ec::MPI_ERR_TAG, "flat={flat}");
            // And with a matching message already queued, validation
            // still wins over the fast-path short-circuit.
            if rank == 0 {
                engine::send(
                    v.as_ptr() as *const u8,
                    1,
                    dt,
                    1,
                    9,
                    COMM_WORLD,
                    SendMode::Standard,
                )
                .unwrap();
            } else {
                // Let the message land, then issue an invalid recv.
                let s = engine::probe(0, 9, COMM_WORLD).unwrap();
                assert_eq!(s.tag, 9);
                let e = engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 0, -1234, COMM_WORLD)
                    .unwrap_err();
                assert_eq!(e.class, ec::MPI_ERR_TAG, "flat={flat}");
                // The valid recv still gets the message afterwards.
                let s = engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 0, 9, COMM_WORLD)
                    .unwrap();
                assert_eq!((buf[0], s.tag), (1, 9));
            }
            engine::finalize().unwrap();
        });
    }
}

/// PROC_NULL blocking ops complete immediately with an empty status on
/// both paths.
#[test]
fn proc_null_fast_path_empty_status() {
    use mpi_abi::abi::constants::MPI_PROC_NULL;
    for flat in [false, true] {
        run_job_ok(JobSpec::new(1).with_flat_match(flat), |_| {
            engine::init().unwrap();
            let dt = dt_i32();
            let v = [1i32];
            let mut buf = [7i32];
            engine::send(
                v.as_ptr() as *const u8,
                1,
                dt,
                MPI_PROC_NULL,
                3,
                COMM_WORLD,
                SendMode::Standard,
            )
            .unwrap();
            let s = engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, MPI_PROC_NULL, 3, COMM_WORLD)
                .unwrap();
            assert_eq!(s.source, MPI_PROC_NULL);
            assert_eq!(s.count_bytes, 0);
            assert_eq!(buf[0], 7, "PROC_NULL recv must not touch the buffer");
            // Probe on PROC_NULL matches immediately with an empty
            // status (MPI 3.0 §3.8) — same short-circuit as recv.
            let p = engine::iprobe(MPI_PROC_NULL, 3, COMM_WORLD).unwrap();
            assert!(matches!(p, Some(s) if s.source == MPI_PROC_NULL && s.count_bytes == 0));
            let s = engine::probe(MPI_PROC_NULL, 3, COMM_WORLD).unwrap();
            assert_eq!(s.source, MPI_PROC_NULL);
            engine::finalize().unwrap();
        });
    }
}

/// Synchronous blocking send (fast path) really waits for the match: the
/// receiver's delayed recv observes it, and both modes agree bit-for-bit
/// on a longer mixed script (the "observably identical" check).
#[test]
fn flat_and_indexed_agree_on_mixed_script() {
    let script = |flat: bool, transport: TransportKind| -> Vec<Vec<i32>> {
        let spec = JobSpec::new(2).with_transport(transport).with_flat_match(flat);
        run_job_ok(spec, |rank| {
            engine::init().unwrap();
            let dt = dt_i32();
            let mut log = Vec::new();
            if rank == 0 {
                for round in 0..20i32 {
                    let tag = round % 3; // rotate over 3 exact buckets
                    let v = [round * 2];
                    let mode =
                        if round % 5 == 0 { SendMode::Sync } else { SendMode::Standard };
                    engine::send(v.as_ptr() as *const u8, 1, dt, 1, tag, COMM_WORLD, mode)
                        .unwrap();
                }
                // Drain the echoes (wildcard source, exact tags).
                for _ in 0..20 {
                    let mut buf = [0i32];
                    let s = engine::recv(
                        buf.as_mut_ptr() as *mut u8,
                        1,
                        dt,
                        mpi_abi::abi::constants::MPI_ANY_SOURCE,
                        7,
                        COMM_WORLD,
                    )
                    .unwrap();
                    log.push(buf[0]);
                    log.push(s.source);
                }
            } else {
                for round in 0..20i32 {
                    let tag = round % 3;
                    let mut buf = [0i32];
                    let s = engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 0, tag, COMM_WORLD)
                        .unwrap();
                    log.push(buf[0]);
                    log.push(s.tag);
                    let echo = [buf[0] + 1];
                    engine::send(
                        echo.as_ptr() as *const u8,
                        1,
                        dt,
                        0,
                        7,
                        COMM_WORLD,
                        SendMode::Standard,
                    )
                    .unwrap();
                }
            }
            engine::finalize().unwrap();
            log
        })
    };
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        let indexed = script(false, transport);
        let flat = script(true, transport);
        assert_eq!(indexed, flat, "fast path must be observably identical ({transport:?})");
    }
}

/// Liveness under backpressure: a flood that overfills one
/// destination's ring (spilling into the per-destination pending
/// queues) while blocking fast-path traffic flows to another
/// destination, all draining cleanly by finalize. The *deterministic*
/// pin of the head-of-line-blocking fix — dst-2 deferred envelopes
/// flushing while dst-1's stay parked — is the unit test
/// `flush_is_keyed_per_destination` in `core/request.rs`, which can
/// observe the pending queues directly.
#[test]
fn backpressure_flood_with_cross_traffic_completes() {
    use mpi_abi::core::transport::SPSC_CAPACITY;
    run_job_ok(JobSpec::new(3), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        if rank == 0 {
            let v = [9i32];
            // Overfill the 0→1 ring: the excess parks in the dst-1
            // pending queue (isend keeps this nonblocking).
            let mut reqs = Vec::new();
            for _ in 0..(SPSC_CAPACITY + 8) {
                reqs.push(
                    engine::isend(
                        v.as_ptr() as *const u8,
                        1,
                        dt,
                        1,
                        4,
                        COMM_WORLD,
                        SendMode::Standard,
                    )
                    .unwrap(),
                );
            }
            // With dst-1 traffic parked, a blocking round-trip with
            // rank 2 still completes (fast path, different ring).
            let ping = [5i32];
            engine::send(ping.as_ptr() as *const u8, 1, dt, 2, 6, COMM_WORLD, SendMode::Standard)
                .unwrap();
            let mut pong = [0i32];
            let s =
                engine::recv(pong.as_mut_ptr() as *mut u8, 1, dt, 2, 6, COMM_WORLD).unwrap();
            assert_eq!((pong[0], s.source), (6, 2));
            // Release rank 1; its messages queue behind the parked
            // flood (per-destination FIFO).
            let go = [1i32];
            engine::send(go.as_ptr() as *const u8, 1, dt, 1, 5, COMM_WORLD, SendMode::Standard)
                .unwrap();
            for r in reqs {
                wait(r).unwrap();
            }
        } else if rank == 1 {
            let mut buf = [0i32];
            for _ in 0..(SPSC_CAPACITY + 8) {
                engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 0, 4, COMM_WORLD).unwrap();
                assert_eq!(buf[0], 9);
            }
            engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 0, 5, COMM_WORLD).unwrap();
        } else {
            let mut buf = [0i32];
            engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 0, 6, COMM_WORLD).unwrap();
            let pong = [6i32];
            engine::send(pong.as_ptr() as *const u8, 1, dt, 0, 6, COMM_WORLD, SendMode::Standard)
                .unwrap();
        }
        engine::finalize().unwrap();
    });
}
