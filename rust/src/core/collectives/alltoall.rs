//! All-to-all collectives — blocking entry points and the
//! [`AlltoallwArgs`] bundle. `MPI_Alltoallw` remains the paper's
//! worst-case ABI-translation scenario (§6.2): a request that owns
//! *vectors of datatype handles* which a translation layer must convert
//! and keep alive until completion; its engine, like every collective's,
//! is a schedule in [`super::sched`].

use super::{coll_begin, coll_recv, coll_send, sched, wait_coll};
use crate::core::world::with_ctx;
use crate::core::{CommId, DtId, RC};

/// `MPI_Alltoall`.
#[allow(clippy::too_many_arguments)]
pub fn alltoall(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::ialltoall(sendbuf, sendcount, sendtype, recvbuf, recvcount, recvtype,
        comm)?)
}

/// `MPI_Alltoallv` (displacements in type extents, MPI-style).
#[allow(clippy::too_many_arguments)]
pub fn alltoallv(
    sendbuf: *const u8,
    sendcounts: &[usize],
    sdispls_elems: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    rdispls_elems: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::ialltoallv(sendbuf, sendcounts, sdispls_elems, sendtype, recvbuf,
        recvcounts, rdispls_elems, recvtype, comm)?)
}

/// The `MPI_Alltoallw` argument bundle: per-peer counts, *byte*
/// displacements, and per-peer datatypes.
#[allow(missing_docs)] // field names mirror the MPI_Alltoallw parameters
pub struct AlltoallwArgs {
    pub sendbuf: *const u8,
    pub sendcounts: Vec<usize>,
    pub sdispls: Vec<isize>,
    pub sendtypes: Vec<DtId>,
    pub recvbuf: *mut u8,
    pub recvcounts: Vec<usize>,
    pub rdispls: Vec<isize>,
    pub recvtypes: Vec<DtId>,
}

/// `MPI_Alltoallw` (blocking).
pub fn alltoallw(args: &AlltoallwArgs, comm: CommId) -> RC<()> {
    wait_coll(sched::ialltoallw(args, comm)?)
}

/// Byte-level alltoall used internally and by benches: every rank sends
/// `blk` bytes to every peer from `send[r*blk..]` into `recv[r*blk..]`.
pub fn alltoall_bytes(send: &[u8], recv: &mut [u8], blk: usize, comm: CommId) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        for r in 0..n {
            if r == cc.my_rank {
                recv[r * blk..(r + 1) * blk].copy_from_slice(&send[r * blk..(r + 1) * blk]);
            } else {
                coll_send(ctx, &cc, r, crate::core::transport::Payload::from_slice(
                    &send[r * blk..(r + 1) * blk]));
            }
        }
        for r in 0..n {
            if r == cc.my_rank {
                continue;
            }
            let p = coll_recv(ctx, &cc, r)?;
            recv[r * blk..r * blk + p.len().min(blk)]
                .copy_from_slice(&p.as_slice()[..p.len().min(blk)]);
        }
        Ok(())
    })
}
