//! SPEC.md can never silently rot: parse its machine-readable tables
//! (delimited by `<!-- *-table:begin/end -->` comments) and assert
//! every value against the live code — the handle-encoding table
//! against `abi::all_predefined_handles()` + the Huffman decoders, and
//! the §5 translation tables against the three ABIs' constants.

use mpi_abi::abi::huffman;
use mpi_abi::api::MpiAbi;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::native_abi::NativeAbi;

fn spec_text() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../SPEC.md");
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// Rows of the table between `<!-- {tag}:begin -->` and `:end`,
/// header and separator rows stripped, each row split into cells.
fn table_rows(spec: &str, tag: &str) -> Vec<Vec<String>> {
    let begin = format!("<!-- {tag}:begin -->");
    let end = format!("<!-- {tag}:end -->");
    let start = spec.find(&begin).unwrap_or_else(|| panic!("SPEC.md lacks {begin}"));
    let stop = spec.find(&end).unwrap_or_else(|| panic!("SPEC.md lacks {end}"));
    assert!(start < stop, "malformed {tag} markers");
    let mut rows = Vec::new();
    for line in spec[start..stop].lines() {
        let line = line.trim();
        if !line.starts_with('|') {
            continue;
        }
        let cells: Vec<String> = line
            .trim_matches('|')
            .split('|')
            .map(|c| c.trim().trim_matches('`').to_string())
            .collect();
        // Skip the header and |---| separator rows.
        if cells.iter().all(|c| c.chars().all(|ch| ch == '-'))
            || ["constant", "type", "function", "cvar"].contains(&cells[0].as_str())
        {
            continue;
        }
        rows.push(cells);
    }
    assert!(!rows.is_empty(), "{tag} has no data rows");
    rows
}

#[test]
fn handle_encoding_table_matches_code() {
    let spec = spec_text();
    let rows = table_rows(&spec, "handle-table");
    let code: Vec<(&'static str, usize)> = mpi_abi::abi::all_predefined_handles();

    // Every SPEC row must name a real constant with the exact value,
    // kind, and encoded fixed size.
    let mut seen = std::collections::HashSet::new();
    for cells in &rows {
        assert_eq!(cells.len(), 4, "malformed row {cells:?}");
        let (name, bits, kind, size) = (&cells[0], &cells[1], &cells[2], &cells[3]);
        let value = usize::from_str_radix(bits.trim_start_matches("0b"), 2)
            .unwrap_or_else(|e| panic!("{name}: bad code {bits:?}: {e}"));
        let code_value = code
            .iter()
            .find(|&&(n, _)| n == name)
            .unwrap_or_else(|| panic!("SPEC row {name} names no constant in the code"))
            .1;
        assert_eq!(value, code_value, "{name}: SPEC says {value:#012b}, code {code_value:#012b}");
        let code_kind = format!("{:?}", huffman::kind_of(value as u16));
        assert_eq!(kind, &code_kind, "{name}: SPEC kind {kind}, decoder says {code_kind}");
        let code_size = huffman::fixed_size_of(value);
        let spec_size = if size == "—" { None } else { Some(size.parse::<usize>().unwrap()) };
        assert_eq!(spec_size, code_size, "{name}: fixed-size column disagrees with the bits");
        assert!(seen.insert(name.clone()), "duplicate SPEC row for {name}");
    }
    // …and every constant in the code must have a SPEC row.
    for (name, _) in &code {
        assert!(seen.contains(*name), "code constant {name} missing from SPEC.md");
    }
    assert_eq!(rows.len(), code.len(), "row count vs inventory");
}

fn cell_i32(cells: &[String], i: usize) -> i32 {
    cells[i].parse().unwrap_or_else(|e| panic!("{cells:?}[{i}]: {e}"))
}

#[test]
fn lock_type_table_matches_code() {
    let spec = spec_text();
    for cells in table_rows(&spec, "locks-table") {
        let (std_v, mpich_v, ompi_v) =
            (cell_i32(&cells, 1), cell_i32(&cells, 2), cell_i32(&cells, 3));
        let per_abi = |excl: bool| {
            if excl {
                (NativeAbi::lock_exclusive(), MpichAbi::lock_exclusive(), OmpiAbi::lock_exclusive())
            } else {
                (NativeAbi::lock_shared(), MpichAbi::lock_shared(), OmpiAbi::lock_shared())
            }
        };
        let (s, m, o) = match cells[0].as_str() {
            "MPI_LOCK_EXCLUSIVE" => per_abi(true),
            "MPI_LOCK_SHARED" => per_abi(false),
            other => panic!("unexpected lock row {other}"),
        };
        assert_eq!((std_v, mpich_v, ompi_v), (s, m, o), "{}", cells[0]);
    }
}

#[test]
fn assertion_bits_table_matches_code() {
    let spec = spec_text();
    let mut seen = 0;
    for cells in table_rows(&spec, "asserts-table") {
        let want: (i32, i32, i32) = match cells[0].as_str() {
            "MPI_MODE_NOCHECK" =>
                (NativeAbi::mode_nocheck(), MpichAbi::mode_nocheck(), OmpiAbi::mode_nocheck()),
            "MPI_MODE_NOSTORE" =>
                (NativeAbi::mode_nostore(), MpichAbi::mode_nostore(), OmpiAbi::mode_nostore()),
            "MPI_MODE_NOPUT" =>
                (NativeAbi::mode_noput(), MpichAbi::mode_noput(), OmpiAbi::mode_noput()),
            "MPI_MODE_NOPRECEDE" => (
                NativeAbi::mode_noprecede(),
                MpichAbi::mode_noprecede(),
                OmpiAbi::mode_noprecede(),
            ),
            "MPI_MODE_NOSUCCEED" => (
                NativeAbi::mode_nosucceed(),
                MpichAbi::mode_nosucceed(),
                OmpiAbi::mode_nosucceed(),
            ),
            other => panic!("unexpected assert row {other}"),
        };
        assert_eq!(
            (cell_i32(&cells, 1), cell_i32(&cells, 2), cell_i32(&cells, 3)),
            want,
            "{}",
            cells[0]
        );
        seen += 1;
    }
    assert_eq!(seen, 5, "all five assertion bits documented");
}

#[test]
fn special_integers_table_matches_code() {
    let spec = spec_text();
    for cells in table_rows(&spec, "specials-table") {
        let want: (i32, i32, i32) = match cells[0].as_str() {
            "MPI_ANY_SOURCE" =>
                (NativeAbi::any_source(), MpichAbi::any_source(), OmpiAbi::any_source()),
            "MPI_ANY_TAG" => (NativeAbi::any_tag(), MpichAbi::any_tag(), OmpiAbi::any_tag()),
            "MPI_PROC_NULL" =>
                (NativeAbi::proc_null(), MpichAbi::proc_null(), OmpiAbi::proc_null()),
            "MPI_UNDEFINED" =>
                (NativeAbi::undefined(), MpichAbi::undefined(), OmpiAbi::undefined()),
            other => panic!("unexpected specials row {other}"),
        };
        assert_eq!(
            (cell_i32(&cells, 1), cell_i32(&cells, 2), cell_i32(&cells, 3)),
            want,
            "{}",
            cells[0]
        );
    }
}

/// SPEC §9: `MPI_Count`/`MPI_Aint` are 64-bit in every configuration.
/// The table's three config columns must each match the width of the
/// one live typedef the code compiles everywhere
/// (`abi::types::{Count, Aint}`).
#[test]
fn integer_width_table_matches_code() {
    let spec = spec_text();
    let mut seen = 0;
    for cells in table_rows(&spec, "widths-table") {
        let code_bits = match cells[0].as_str() {
            "MPI_Count" => 8 * std::mem::size_of::<mpi_abi::abi::types::Count>(),
            "MPI_Aint" => 8 * std::mem::size_of::<mpi_abi::abi::types::Aint>(),
            other => panic!("unexpected widths row {other}"),
        };
        for col in 1..=3 {
            assert_eq!(cell_i32(&cells, col) as usize, code_bits, "{} col {col}", cells[0]);
        }
        assert_eq!(code_bits, 64, "{} must be 64-bit", cells[0]);
        seen += 1;
    }
    assert_eq!(seen, 2, "both wide integer types documented");
}

/// SPEC §9: every `_c` family row names a `WRAP_` symbol that resolves
/// in BOTH backends' wrap tables (the dlsym probe Mukautuva's init
/// would fail on), and the classic column names the matching MPI call.
#[test]
fn bigcount_symbol_table_matches_code() {
    use mpi_abi::muk::{symbols, Backend};
    let spec = spec_text();
    let mpich = symbols(Backend::Mpich);
    let ompi = symbols(Backend::Ompi);
    let mut seen = 0;
    for cells in table_rows(&spec, "bigcount-table") {
        let (func, sym) = (&cells[0], &cells[1]);
        assert!(func.starts_with("MPI_") && func.ends_with("_c"), "malformed function {func}");
        assert!(sym.starts_with("WRAP_") && sym.ends_with("_c"), "malformed symbol {sym}");
        assert!(mpich.has(sym), "{sym} missing from the MPICH-backed wrap table");
        assert!(ompi.has(sym), "{sym} missing from the OMPI-backed wrap table");
        seen += 1;
    }
    assert_eq!(seen, 9, "all nine _c entry points documented");
    // The guard the _c family exists to avoid: classic get_count
    // reports MPI_UNDEFINED rather than truncating (MPI-4.1 §3.2.5).
    assert!(
        spec.contains("must return `MPI_UNDEFINED` when the true count exceeds `INT_MAX`"),
        "SPEC.md lost the truncation-is-an-error clause"
    );
}

/// SPEC §10: the rendezvous contract stays documented alongside its
/// tunable.
#[test]
fn rendezvous_section_exists() {
    let spec = spec_text();
    for needle in [
        "## 10. The eager/rendezvous protocol switch",
        "MPI_ABI_RNDV_THRESHOLD",
        "Matching is protocol-blind",
        "Buffering is bounded",
        "BENCH_PR6.json",
    ] {
        assert!(spec.contains(needle), "SPEC.md lost its rendezvous clause {needle:?}");
    }
}

/// SPEC §11: the MPI_T zero-page constants table must match
/// `abi::constants::MPI_T_CONSTANTS` exactly — same names, same values,
/// same order (indices into the registries are a fixed ABI surface).
#[test]
fn mpit_constants_table_matches_code() {
    let spec = spec_text();
    let rows = table_rows(&spec, "mpit-table");
    let code = mpi_abi::abi::constants::MPI_T_CONSTANTS;
    assert_eq!(rows.len(), code.len(), "row count vs MPI_T_CONSTANTS");
    for (cells, &(name, value)) in rows.iter().zip(code) {
        assert_eq!(cells[0], name, "SPEC order must match code order");
        assert_eq!(cell_i32(cells, 1), value, "{name}");
    }
}

/// SPEC §11: every MPI_T row names a `WRAP_t_` symbol that resolves in
/// BOTH backends' wrap tables, and the pvar registry order written in
/// prose stays the code's order.
#[test]
fn mpit_symbol_table_matches_code() {
    use mpi_abi::muk::{symbols, Backend};
    let spec = spec_text();
    let mpich = symbols(Backend::Mpich);
    let ompi = symbols(Backend::Ompi);
    let mut seen = 0;
    for cells in table_rows(&spec, "mpit-symbols-table") {
        let (func, sym) = (&cells[0], &cells[1]);
        assert!(func.starts_with("MPI_T_"), "malformed function {func}");
        assert!(sym.starts_with("WRAP_t_"), "malformed symbol {sym}");
        assert!(mpich.has(sym), "{sym} missing from the MPICH-backed wrap table");
        assert!(ompi.has(sym), "{sym} missing from the OMPI-backed wrap table");
        seen += 1;
    }
    assert_eq!(seen, 14, "all fourteen MPI_T entry points documented");
    // The prose registry listing must track `core::obs::PVARS` order.
    for name in [
        "`sends_posted`",
        "`wildcard_matches`",
        "`rndv_inflight_peak`",
        "`sched_reuses`",
        "MPI_T_ERR_CVAR_SET_NEVER",
    ] {
        assert!(spec.contains(name), "SPEC.md §11 lost its mention of {name}");
    }
}

/// SPEC §12: the ULFM error classes are part of the ABI error space —
/// the documented values match the code, the classes are registered in
/// `ERROR_CLASSES` (so `MPI_Error_string` covers them), and every
/// representation round-trips them through its error-code space.
#[test]
fn ulfm_error_class_table_matches_code() {
    use mpi_abi::abi::errors as ec;
    use mpi_abi::impls::mpich::MpichRepr;
    use mpi_abi::impls::ompi::OmpiRepr;
    use mpi_abi::impls::repr::Repr;
    use mpi_abi::native_abi::NativeRepr;
    let spec = spec_text();
    let mut seen = 0;
    for cells in table_rows(&spec, "ulfm-errors-table") {
        let want = match cells[0].as_str() {
            "MPI_ERR_PROC_FAILED" => ec::MPI_ERR_PROC_FAILED,
            "MPI_ERR_PROC_FAILED_PENDING" => ec::MPI_ERR_PROC_FAILED_PENDING,
            "MPI_ERR_REVOKED" => ec::MPI_ERR_REVOKED,
            other => panic!("unexpected ULFM error row {other}"),
        };
        assert_eq!(cell_i32(&cells, 1), want, "{}", cells[0]);
        assert!(
            mpi_abi::abi::ERROR_CLASSES.iter().any(|&(n, v)| n == cells[0] && v == want),
            "{} missing from ERROR_CLASSES",
            cells[0]
        );
        assert_eq!(MpichRepr::class_of_err(MpichRepr::err_from_class(want)), want);
        assert_eq!(OmpiRepr::class_of_err(OmpiRepr::err_from_class(want)), want);
        assert_eq!(NativeRepr::class_of_err(NativeRepr::err_from_class(want)), want);
        seen += 1;
    }
    assert_eq!(seen, 3, "all three ULFM error classes documented");
}

/// SPEC §12: every ULFM row names a `WRAP_` symbol that resolves in
/// BOTH backends' wrap tables, and the prose keeps the contract's
/// load-bearing clauses (the kill knob, the no-hang guarantee, the
/// three failure pvars).
#[test]
fn ulfm_symbol_table_matches_code() {
    use mpi_abi::muk::{symbols, Backend};
    let spec = spec_text();
    let mpich = symbols(Backend::Mpich);
    let ompi = symbols(Backend::Ompi);
    let mut seen = 0;
    for cells in table_rows(&spec, "ulfm-symbols-table") {
        let (func, sym) = (&cells[0], &cells[1]);
        assert!(func.starts_with("MPI_Comm_"), "malformed function {func}");
        assert!(sym.starts_with("WRAP_comm_"), "malformed symbol {sym}");
        assert!(mpich.has(sym), "{sym} missing from the MPICH-backed wrap table");
        assert!(ompi.has(sym), "{sym} missing from the OMPI-backed wrap table");
        seen += 1;
    }
    assert_eq!(seen, 5, "all five ULFM entry points documented");
    for needle in [
        "MPI_ABI_KILL",
        "never hang",
        "`ranks_failed`",
        "`ops_failed_proc`",
        "`comms_revoked`",
    ] {
        assert!(spec.contains(needle), "SPEC.md §12 lost its clause {needle:?}");
    }
}

/// SPEC §13: the collective-algorithm force codes, their cvar names and
/// indices, and the `MPI_ABI_COLL_ALGO` spelling of each algorithm are
/// a fixed ABI surface — machine-checked against `core::collectives`
/// and `core::obs`, including a round-trip of every name through the
/// environment-override parser.
#[test]
fn coll_algo_table_matches_code() {
    use mpi_abi::core::collectives as c;
    use mpi_abi::core::obs;
    let spec = spec_text();
    let mut seen = 0;
    for cells in table_rows(&spec, "coll-algos-table") {
        assert_eq!(cells.len(), 5, "malformed row {cells:?}");
        let (cvar, idx, op, code, algo) =
            (&cells[0], cell_i32(&cells, 1), &cells[2], cell_i32(&cells, 3) as u8, &cells[4]);
        let want_idx = match op.as_str() {
            "allreduce" => obs::CVAR_COLL_ALLREDUCE_ALGO,
            "allgather" => obs::CVAR_COLL_ALLGATHER_ALGO,
            "alltoall" => obs::CVAR_COLL_ALLTOALL_ALGO,
            other => panic!("unexpected operation row {other}"),
        };
        assert_eq!(idx as usize, want_idx, "{op} cvar index");
        assert_eq!(cvar, obs::CVARS[want_idx].name, "{op} cvar name");
        let want_code = match (op.as_str(), algo.as_str()) {
            ("allreduce", "binomial") => c::ALLREDUCE_BINOMIAL,
            ("allreduce", "ring") => c::ALLREDUCE_RING,
            ("allreduce", "recursive_doubling") => c::ALLREDUCE_RECURSIVE_DOUBLING,
            ("allreduce", "rabenseifner") => c::ALLREDUCE_RABENSEIFNER,
            ("allgather", "gather_bcast") => c::ALLGATHER_GATHER_BCAST,
            ("allgather", "ring") => c::ALLGATHER_RING,
            ("alltoall", "pairwise") => c::ALLTOALL_PAIRWISE,
            ("alltoall", "bruck") => c::ALLTOALL_BRUCK,
            (o, a) => panic!("unexpected algorithm row {o}/{a}"),
        };
        assert_eq!(code, want_code, "{op}/{algo} force code");
        let f = c::parse_coll_algo(&format!("{op}={algo}"));
        let parsed = match op.as_str() {
            "allreduce" => f.allreduce,
            "allgather" => f.allgather,
            _ => f.alltoall,
        };
        assert_eq!(parsed, want_code, "parse_coll_algo({op}={algo})");
        seen += 1;
    }
    assert_eq!(seen, 8, "all eight (operation, algorithm) rows documented");
    for needle in [
        "MPI_ABI_COLL_ALGO",
        "`coll_sel_binomial`",
        "`coll_allreduce_algo`",
        "BENCH_PR10.json",
        "Pareto frontier",
    ] {
        assert!(spec.contains(needle), "SPEC.md §13 lost its clause {needle:?}");
    }
}

#[test]
fn lifecycle_and_session_sections_exist() {
    let spec = spec_text();
    for needle in [
        "## 6. Initialization lifecycle",
        "## 7. Request lifecycle and message matching",
        "Posted order × arrival order",
        "MPI_ABI_FLAT_MATCH",
        "MPI_Comm_create_from_group",
        "mpi://WORLD",
        "MPI_SESSION_NULL",
    ] {
        assert!(spec.contains(needle), "SPEC.md lost its section mentioning {needle:?}");
    }
}
