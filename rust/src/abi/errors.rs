//! Standard-ABI error classes.
//!
//! `MPI_SUCCESS == 0` is required; the classes are small consecutive
//! positive integers (unique, so errors can be identified precisely). Each
//! backend implementation uses its *own* error numbering internally —
//! Mukautuva's `RETURN_CODE_IMPL_TO_MUK` translation (§6.2) maps them back
//! to these values, with the success fast path inlined.

/// Error classes of the standard ABI. Values are the ABI contract.
pub const MPI_SUCCESS: i32 = 0;
/// Error class `MPI_ERR_BUFFER` (the value is part of the ABI contract).
pub const MPI_ERR_BUFFER: i32 = 1;
/// Error class `MPI_ERR_COUNT` (the value is part of the ABI contract).
pub const MPI_ERR_COUNT: i32 = 2;
/// Error class `MPI_ERR_TYPE` (the value is part of the ABI contract).
pub const MPI_ERR_TYPE: i32 = 3;
/// Error class `MPI_ERR_TAG` (the value is part of the ABI contract).
pub const MPI_ERR_TAG: i32 = 4;
/// Error class `MPI_ERR_COMM` (the value is part of the ABI contract).
pub const MPI_ERR_COMM: i32 = 5;
/// Error class `MPI_ERR_RANK` (the value is part of the ABI contract).
pub const MPI_ERR_RANK: i32 = 6;
/// Error class `MPI_ERR_REQUEST` (the value is part of the ABI contract).
pub const MPI_ERR_REQUEST: i32 = 7;
/// Error class `MPI_ERR_ROOT` (the value is part of the ABI contract).
pub const MPI_ERR_ROOT: i32 = 8;
/// Error class `MPI_ERR_GROUP` (the value is part of the ABI contract).
pub const MPI_ERR_GROUP: i32 = 9;
/// Error class `MPI_ERR_OP` (the value is part of the ABI contract).
pub const MPI_ERR_OP: i32 = 10;
/// Error class `MPI_ERR_TOPOLOGY` (the value is part of the ABI contract).
pub const MPI_ERR_TOPOLOGY: i32 = 11;
/// Error class `MPI_ERR_DIMS` (the value is part of the ABI contract).
pub const MPI_ERR_DIMS: i32 = 12;
/// Error class `MPI_ERR_ARG` (the value is part of the ABI contract).
pub const MPI_ERR_ARG: i32 = 13;
/// Error class `MPI_ERR_UNKNOWN` (the value is part of the ABI contract).
pub const MPI_ERR_UNKNOWN: i32 = 14;
/// Error class `MPI_ERR_TRUNCATE` (the value is part of the ABI contract).
pub const MPI_ERR_TRUNCATE: i32 = 15;
/// Error class `MPI_ERR_OTHER` (the value is part of the ABI contract).
pub const MPI_ERR_OTHER: i32 = 16;
/// Error class `MPI_ERR_INTERN` (the value is part of the ABI contract).
pub const MPI_ERR_INTERN: i32 = 17;
/// Error class `MPI_ERR_IN_STATUS` (the value is part of the ABI contract).
pub const MPI_ERR_IN_STATUS: i32 = 18;
/// Error class `MPI_ERR_PENDING` (the value is part of the ABI contract).
pub const MPI_ERR_PENDING: i32 = 19;
/// Error class `MPI_ERR_KEYVAL` (the value is part of the ABI contract).
pub const MPI_ERR_KEYVAL: i32 = 20;
/// Error class `MPI_ERR_NO_MEM` (the value is part of the ABI contract).
pub const MPI_ERR_NO_MEM: i32 = 21;
/// Error class `MPI_ERR_BASE` (the value is part of the ABI contract).
pub const MPI_ERR_BASE: i32 = 22;
/// Error class `MPI_ERR_INFO_KEY` (the value is part of the ABI contract).
pub const MPI_ERR_INFO_KEY: i32 = 23;
/// Error class `MPI_ERR_INFO_VALUE` (the value is part of the ABI contract).
pub const MPI_ERR_INFO_VALUE: i32 = 24;
/// Error class `MPI_ERR_INFO_NOKEY` (the value is part of the ABI contract).
pub const MPI_ERR_INFO_NOKEY: i32 = 25;
/// Error class `MPI_ERR_SPAWN` (the value is part of the ABI contract).
pub const MPI_ERR_SPAWN: i32 = 26;
/// Error class `MPI_ERR_PORT` (the value is part of the ABI contract).
pub const MPI_ERR_PORT: i32 = 27;
/// Error class `MPI_ERR_SERVICE` (the value is part of the ABI contract).
pub const MPI_ERR_SERVICE: i32 = 28;
/// Error class `MPI_ERR_NAME` (the value is part of the ABI contract).
pub const MPI_ERR_NAME: i32 = 29;
/// Error class `MPI_ERR_WIN` (the value is part of the ABI contract).
pub const MPI_ERR_WIN: i32 = 30;
/// Error class `MPI_ERR_SIZE` (the value is part of the ABI contract).
pub const MPI_ERR_SIZE: i32 = 31;
/// Error class `MPI_ERR_DISP` (the value is part of the ABI contract).
pub const MPI_ERR_DISP: i32 = 32;
/// Error class `MPI_ERR_INFO` (the value is part of the ABI contract).
pub const MPI_ERR_INFO: i32 = 33;
/// Error class `MPI_ERR_LOCKTYPE` (the value is part of the ABI contract).
pub const MPI_ERR_LOCKTYPE: i32 = 34;
/// Error class `MPI_ERR_ASSERT` (the value is part of the ABI contract).
pub const MPI_ERR_ASSERT: i32 = 35;
/// Error class `MPI_ERR_RMA_CONFLICT` (the value is part of the ABI contract).
pub const MPI_ERR_RMA_CONFLICT: i32 = 36;
/// Error class `MPI_ERR_RMA_SYNC` (the value is part of the ABI contract).
pub const MPI_ERR_RMA_SYNC: i32 = 37;
/// Error class `MPI_ERR_FILE` (the value is part of the ABI contract).
pub const MPI_ERR_FILE: i32 = 38;
/// Error class `MPI_ERR_NOT_SAME` (the value is part of the ABI contract).
pub const MPI_ERR_NOT_SAME: i32 = 39;
/// Error class `MPI_ERR_AMODE` (the value is part of the ABI contract).
pub const MPI_ERR_AMODE: i32 = 40;
/// Error class `MPI_ERR_UNSUPPORTED_DATAREP` (the value is part of the ABI contract).
pub const MPI_ERR_UNSUPPORTED_DATAREP: i32 = 41;
/// Error class `MPI_ERR_UNSUPPORTED_OPERATION` (the value is part of the ABI contract).
pub const MPI_ERR_UNSUPPORTED_OPERATION: i32 = 42;
/// Error class `MPI_ERR_NO_SUCH_FILE` (the value is part of the ABI contract).
pub const MPI_ERR_NO_SUCH_FILE: i32 = 43;
/// Error class `MPI_ERR_FILE_EXISTS` (the value is part of the ABI contract).
pub const MPI_ERR_FILE_EXISTS: i32 = 44;
/// Error class `MPI_ERR_BAD_FILE` (the value is part of the ABI contract).
pub const MPI_ERR_BAD_FILE: i32 = 45;
/// Error class `MPI_ERR_ACCESS` (the value is part of the ABI contract).
pub const MPI_ERR_ACCESS: i32 = 46;
/// Error class `MPI_ERR_NO_SPACE` (the value is part of the ABI contract).
pub const MPI_ERR_NO_SPACE: i32 = 47;
/// Error class `MPI_ERR_QUOTA` (the value is part of the ABI contract).
pub const MPI_ERR_QUOTA: i32 = 48;
/// Error class `MPI_ERR_READ_ONLY` (the value is part of the ABI contract).
pub const MPI_ERR_READ_ONLY: i32 = 49;
/// Error class `MPI_ERR_FILE_IN_USE` (the value is part of the ABI contract).
pub const MPI_ERR_FILE_IN_USE: i32 = 50;
/// Error class `MPI_ERR_DUP_DATAREP` (the value is part of the ABI contract).
pub const MPI_ERR_DUP_DATAREP: i32 = 51;
/// Error class `MPI_ERR_CONVERSION` (the value is part of the ABI contract).
pub const MPI_ERR_CONVERSION: i32 = 52;
/// Error class `MPI_ERR_IO` (the value is part of the ABI contract).
pub const MPI_ERR_IO: i32 = 53;
/// Error class `MPI_ERR_RMA_RANGE` (the value is part of the ABI contract).
pub const MPI_ERR_RMA_RANGE: i32 = 54;
/// Error class `MPI_ERR_RMA_ATTACH` (the value is part of the ABI contract).
pub const MPI_ERR_RMA_ATTACH: i32 = 55;
/// Error class `MPI_ERR_RMA_SHARED` (the value is part of the ABI contract).
pub const MPI_ERR_RMA_SHARED: i32 = 56;
/// Error class `MPI_ERR_RMA_FLAVOR` (the value is part of the ABI contract).
pub const MPI_ERR_RMA_FLAVOR: i32 = 57;
/// Error class `MPI_ERR_SESSION` (the value is part of the ABI contract).
pub const MPI_ERR_SESSION: i32 = 58;
/// Error class `MPI_ERR_PROC_ABORTED` (the value is part of the ABI contract).
pub const MPI_ERR_PROC_ABORTED: i32 = 59;
/// Error class `MPI_ERR_VALUE_TOO_LARGE` (the value is part of the ABI contract).
pub const MPI_ERR_VALUE_TOO_LARGE: i32 = 60;
/// Error class `MPI_ERR_ERRHANDLER` (the value is part of the ABI contract).
pub const MPI_ERR_ERRHANDLER: i32 = 61;
/// Error class `MPI_T_ERR_NOT_INITIALIZED`: an MPI_T call before
/// `MPI_T_init_thread` (the tools interface has its own init epoch).
pub const MPI_T_ERR_NOT_INITIALIZED: i32 = 62;
/// Error class `MPI_T_ERR_INVALID_INDEX`: cvar/pvar index out of range.
pub const MPI_T_ERR_INVALID_INDEX: i32 = 63;
/// Error class `MPI_T_ERR_INVALID_HANDLE`: stale or never-allocated
/// cvar/pvar handle.
pub const MPI_T_ERR_INVALID_HANDLE: i32 = 64;
/// Error class `MPI_T_ERR_INVALID_SESSION`: stale or never-created pvar
/// session.
pub const MPI_T_ERR_INVALID_SESSION: i32 = 65;
/// Error class `MPI_T_ERR_CVAR_SET_NEVER`: write attempted on a cvar
/// whose scope is read-only.
pub const MPI_T_ERR_CVAR_SET_NEVER: i32 = 66;
/// Error class `MPI_ERR_PROC_FAILED` (ULFM): the operation's peer
/// process has failed; the operation completed with an error instead of
/// hanging.
pub const MPI_ERR_PROC_FAILED: i32 = 67;
/// Error class `MPI_ERR_PROC_FAILED_PENDING` (ULFM): a wildcard receive
/// cannot complete because a potential matching sender has failed; the
/// request stays pending until the failure is acknowledged.
pub const MPI_ERR_PROC_FAILED_PENDING: i32 = 68;
/// Error class `MPI_ERR_REVOKED` (ULFM): the communicator has been
/// revoked by `MPI_Comm_revoke`; all non-agreement operations on it fail.
pub const MPI_ERR_REVOKED: i32 = 69;
/// Last predefined error class (`MPI_ERR_LASTCODE` floor).
pub const MPI_ERR_LASTCODE: i32 = 128;

/// Names + values of all predefined classes.
pub const ERROR_CLASSES: &[(&str, i32)] = &[
    ("MPI_SUCCESS", MPI_SUCCESS),
    ("MPI_ERR_BUFFER", MPI_ERR_BUFFER),
    ("MPI_ERR_COUNT", MPI_ERR_COUNT),
    ("MPI_ERR_TYPE", MPI_ERR_TYPE),
    ("MPI_ERR_TAG", MPI_ERR_TAG),
    ("MPI_ERR_COMM", MPI_ERR_COMM),
    ("MPI_ERR_RANK", MPI_ERR_RANK),
    ("MPI_ERR_REQUEST", MPI_ERR_REQUEST),
    ("MPI_ERR_ROOT", MPI_ERR_ROOT),
    ("MPI_ERR_GROUP", MPI_ERR_GROUP),
    ("MPI_ERR_OP", MPI_ERR_OP),
    ("MPI_ERR_TOPOLOGY", MPI_ERR_TOPOLOGY),
    ("MPI_ERR_DIMS", MPI_ERR_DIMS),
    ("MPI_ERR_ARG", MPI_ERR_ARG),
    ("MPI_ERR_UNKNOWN", MPI_ERR_UNKNOWN),
    ("MPI_ERR_TRUNCATE", MPI_ERR_TRUNCATE),
    ("MPI_ERR_OTHER", MPI_ERR_OTHER),
    ("MPI_ERR_INTERN", MPI_ERR_INTERN),
    ("MPI_ERR_IN_STATUS", MPI_ERR_IN_STATUS),
    ("MPI_ERR_PENDING", MPI_ERR_PENDING),
    ("MPI_ERR_KEYVAL", MPI_ERR_KEYVAL),
    ("MPI_ERR_NO_MEM", MPI_ERR_NO_MEM),
    ("MPI_ERR_INFO_KEY", MPI_ERR_INFO_KEY),
    ("MPI_ERR_INFO_VALUE", MPI_ERR_INFO_VALUE),
    ("MPI_ERR_INFO_NOKEY", MPI_ERR_INFO_NOKEY),
    ("MPI_ERR_SESSION", MPI_ERR_SESSION),
    ("MPI_ERR_PROC_ABORTED", MPI_ERR_PROC_ABORTED),
    ("MPI_ERR_VALUE_TOO_LARGE", MPI_ERR_VALUE_TOO_LARGE),
    ("MPI_ERR_ERRHANDLER", MPI_ERR_ERRHANDLER),
    ("MPI_T_ERR_NOT_INITIALIZED", MPI_T_ERR_NOT_INITIALIZED),
    ("MPI_T_ERR_INVALID_INDEX", MPI_T_ERR_INVALID_INDEX),
    ("MPI_T_ERR_INVALID_HANDLE", MPI_T_ERR_INVALID_HANDLE),
    ("MPI_T_ERR_INVALID_SESSION", MPI_T_ERR_INVALID_SESSION),
    ("MPI_T_ERR_CVAR_SET_NEVER", MPI_T_ERR_CVAR_SET_NEVER),
    ("MPI_ERR_PROC_FAILED", MPI_ERR_PROC_FAILED),
    ("MPI_ERR_PROC_FAILED_PENDING", MPI_ERR_PROC_FAILED_PENDING),
    ("MPI_ERR_REVOKED", MPI_ERR_REVOKED),
];

/// Human-readable message for `MPI_Error_string`.
pub fn error_string(class: i32) -> &'static str {
    match class {
        MPI_SUCCESS => "No error",
        MPI_ERR_BUFFER => "Invalid buffer pointer",
        MPI_ERR_COUNT => "Invalid count argument",
        MPI_ERR_TYPE => "Invalid datatype argument",
        MPI_ERR_TAG => "Invalid tag argument",
        MPI_ERR_COMM => "Invalid communicator",
        MPI_ERR_RANK => "Invalid rank",
        MPI_ERR_REQUEST => "Invalid request",
        MPI_ERR_ROOT => "Invalid root",
        MPI_ERR_GROUP => "Invalid group",
        MPI_ERR_OP => "Invalid reduction operation",
        MPI_ERR_ARG => "Invalid argument",
        MPI_ERR_TRUNCATE => "Message truncated on receive",
        MPI_ERR_OTHER => "Known error not in this list",
        MPI_ERR_INTERN => "Internal MPI error",
        MPI_ERR_IN_STATUS => "Error code is in status",
        MPI_ERR_PENDING => "Pending request",
        MPI_ERR_KEYVAL => "Invalid keyval",
        MPI_ERR_NO_MEM => "Out of memory",
        MPI_ERR_INFO_KEY => "Invalid info key",
        MPI_ERR_INFO_VALUE => "Invalid info value",
        MPI_ERR_INFO_NOKEY => "No such info key",
        MPI_ERR_SESSION => "Invalid session",
        MPI_ERR_PROC_ABORTED => "A peer process aborted",
        MPI_ERR_UNKNOWN => "Unknown error",
        MPI_T_ERR_NOT_INITIALIZED => "MPI_T interface not initialized",
        MPI_T_ERR_INVALID_INDEX => "Invalid MPI_T variable index",
        MPI_T_ERR_INVALID_HANDLE => "Invalid MPI_T handle",
        MPI_T_ERR_INVALID_SESSION => "Invalid MPI_T performance session",
        MPI_T_ERR_CVAR_SET_NEVER => "Control variable cannot be set",
        MPI_ERR_PROC_FAILED => "A peer process has failed",
        MPI_ERR_PROC_FAILED_PENDING => "A process failure is pending on a wildcard receive",
        MPI_ERR_REVOKED => "Communicator has been revoked",
        _ => "Unknown error class",
    }
}

/// Class name lookup (diagnostics; mirrors `MPI_Error_class` + name table).
pub fn error_class_name(class: i32) -> Option<&'static str> {
    ERROR_CLASSES.iter().find(|&&(_, v)| v == class).map(|&(n, _)| n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn success_is_zero() {
        assert_eq!(MPI_SUCCESS, 0);
    }

    #[test]
    fn classes_unique_positive_below_lastcode() {
        let mut seen = std::collections::HashSet::new();
        for &(name, v) in ERROR_CLASSES {
            assert!(seen.insert(v), "{name} duplicated");
            assert!(v >= 0 && v <= MPI_ERR_LASTCODE, "{name} out of range");
        }
    }

    #[test]
    fn strings_exist_for_all_classes() {
        for &(_, v) in ERROR_CLASSES {
            assert!(!error_string(v).is_empty());
        }
        assert_eq!(error_string(MPI_SUCCESS), "No error");
    }

    #[test]
    fn name_lookup() {
        assert_eq!(error_class_name(MPI_ERR_TRUNCATE), Some("MPI_ERR_TRUNCATE"));
        assert_eq!(error_class_name(9999), None);
    }
}
