//! The Open-MPI-like implementation ABI.
//!
//! Handles are **pointers to incomplete structs** (§3.3): the compiler
//! type-checks them, but their values are link-time addresses of global
//! descriptor objects — *not* compile-time constants. Datatype size
//! queries dereference the descriptor (the `opal_datatype_type_size`
//! path quoted in §3.3), and the descriptor is deliberately sized like
//! Open MPI's (352 bytes) so the cache behaviour is comparable.
//!
//! The status layout is Open MPI's (`_cancelled` + `size_t _ucount`
//! after the three public fields), and the wildcard integers use Open
//! MPI's values (`MPI_ANY_SOURCE = -1`, `MPI_PROC_NULL = -2`).

use once_cell::sync::Lazy;

use super::repr::{Backed, Repr};
use crate::api::{dt_to_abi_const, op_to_abi_const, Dt, OpName};
use crate::core::request::StatusCore;
use crate::core::{err, CommId, DtId, ErrhId, GroupId, InfoId, OpId, RC, ReqId, SessionId, WinId};

/// The public ABI type.
pub type OmpiAbi = Backed<OmpiRepr>;

/// Descriptor object kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
#[allow(missing_docs)] // variants mirror the handle kinds 1:1
pub enum DescKind {
    Comm = 1,
    Group,
    Datatype,
    Op,
    Request,
    Errhandler,
    Info,
    Win,
    Session,
}

/// Magic word every live descriptor carries ("OMPI").
pub const DESC_MAGIC: u32 = 0x4F4D_5049;
const NULL_ID: u32 = u32::MAX;

/// The descriptor every handle points to. Padded to 352 bytes — the
/// paper's "352-byte struct" for Open MPI datatypes — so size lookups
/// touch realistic cache footprints.
#[repr(C)]
pub struct Desc {
    /// [`DESC_MAGIC`] when live (cast-misuse detection).
    pub magic: u32,
    /// What kind of object this descriptor represents.
    pub kind: DescKind,
    /// Predefined descriptors are never freed.
    pub predefined: bool,
    /// The engine object id this descriptor wraps.
    pub engine_id: u32,
    /// Datatype size cache (what `opal_datatype_type_size` loads).
    pub size: i32,
    /// Object name (datatype names for the predefined descriptors).
    pub name: [u8; 64],
    _pad: [u8; 352 - 4 - 1 - 1 - 4 - 4 - 64 - 2],
}

const _: () = assert!(core::mem::size_of::<Desc>() == 352);

impl Desc {
    fn new(kind: DescKind, engine_id: u32, size: i32, predefined: bool) -> Desc {
        Desc {
            magic: DESC_MAGIC,
            kind,
            predefined,
            engine_id,
            size,
            name: [0; 64],
            _pad: [0; 352 - 4 - 1 - 1 - 4 - 4 - 64 - 2],
        }
    }

    fn leak(kind: DescKind, engine_id: u32, size: i32) -> &'static Desc {
        Box::leak(Box::new(Desc::new(kind, engine_id, size, true)))
    }
}

// Descriptors are immutable after creation; sharing across rank threads
// is sound.
unsafe impl Sync for Desc {}
unsafe impl Send for Desc {}

macro_rules! ompi_handle {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq)]
        pub struct $name(pub *const Desc);

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}({:p})", stringify!($name), self.0)
            }
        }
    };
}

ompi_handle!(
    /// `MPI_Comm` = `struct ompi_communicator_t *`.
    OmpiComm
);
ompi_handle!(
    /// `MPI_Datatype` = `struct ompi_datatype_t *`.
    OmpiDatatype
);
ompi_handle!(
    /// `MPI_Op` = `struct ompi_op_t *`.
    OmpiOp
);
ompi_handle!(
    /// `MPI_Request` = `struct ompi_request_t *`.
    OmpiRequest
);
ompi_handle!(
    /// `MPI_Group` = `struct ompi_group_t *`.
    OmpiGroup
);
ompi_handle!(
    /// `MPI_Errhandler` = `struct ompi_errhandler_t *`.
    OmpiErrhandler
);
ompi_handle!(
    /// `MPI_Info` = `struct ompi_info_t *`.
    OmpiInfo
);
ompi_handle!(
    /// `MPI_Win` = `struct ompi_win_t *`.
    OmpiWin
);
ompi_handle!(
    /// `MPI_Session` = `struct ompi_instance_t *` (Open MPI calls the
    /// sessions object an "instance").
    OmpiSession
);

// --- Predefined descriptor globals (the "link-time constants") ---------------

static COMM_WORLD_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Comm, 0, 0));
static COMM_SELF_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Comm, 1, 0));
static COMM_NULL_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Comm, NULL_ID, 0));
static REQUEST_NULL_DESC: Lazy<&'static Desc> =
    Lazy::new(|| Desc::leak(DescKind::Request, NULL_ID, 0));
#[allow(dead_code)] // part of the ABI surface even if unreferenced internally
static GROUP_NULL_DESC: Lazy<&'static Desc> =
    Lazy::new(|| Desc::leak(DescKind::Group, NULL_ID, 0));
static GROUP_EMPTY_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Group, 0, 0));
static ERRH_FATAL_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Errhandler, 0, 0));
static ERRH_RETURN_DESC: Lazy<&'static Desc> =
    Lazy::new(|| Desc::leak(DescKind::Errhandler, 1, 0));
static ERRH_ABORT_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Errhandler, 2, 0));
static INFO_NULL_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Info, NULL_ID, 0));
static INFO_ENV_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Info, 0, 0));
static WIN_NULL_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Win, NULL_ID, 0));
static SESSION_NULL_DESC: Lazy<&'static Desc> =
    Lazy::new(|| Desc::leak(DescKind::Session, NULL_ID, 0));
#[allow(dead_code)] // part of the ABI surface even if unreferenced internally
static OP_NULL_DESC: Lazy<&'static Desc> = Lazy::new(|| Desc::leak(DescKind::Op, NULL_ID, 0));

/// Builtin datatype descriptors, indexed by engine dt id.
static DT_DESCS: Lazy<Vec<&'static Desc>> = Lazy::new(|| {
    crate::abi::datatypes::PREDEFINED_DATATYPES
        .iter()
        .enumerate()
        .map(|(i, &(name, abi))| {
            let size = crate::abi::datatypes::platform_size_of(abi).unwrap_or(0) as i32;
            let d = Box::leak(Box::new(Desc::new(DescKind::Datatype, i as u32, size, true)));
            let n = name.as_bytes();
            let len = n.len().min(63);
            d.name[..len].copy_from_slice(&n[..len]);
            &*d
        })
        .collect()
});

/// Builtin op descriptors, indexed by engine op id.
static OP_DESCS: Lazy<Vec<&'static Desc>> = Lazy::new(|| {
    (0..crate::core::reserved::NUM_BUILTIN_OPS)
        .map(|i| Desc::leak(DescKind::Op, i, 0))
        .collect()
});

// --- Special integers: Open MPI's values --------------------------------------

/// `MPI_ANY_SOURCE` in Open MPI's numbering.
pub const MPI_ANY_SOURCE: i32 = -1;
/// `MPI_ANY_TAG` in Open MPI's numbering.
pub const MPI_ANY_TAG: i32 = -1;
/// `MPI_PROC_NULL` in Open MPI's numbering.
pub const MPI_PROC_NULL: i32 = -2;
/// `MPI_ROOT` in Open MPI's numbering.
pub const MPI_ROOT: i32 = -4;
/// `MPI_UNDEFINED` in Open MPI's numbering.
pub const MPI_UNDEFINED: i32 = -32766;
/// `MPI_COMM_TYPE_SHARED` in Open MPI's numbering (0 — differs from
/// MPICH's 1, the §5.4 special-int translation hazard again).
pub const MPI_COMM_TYPE_SHARED: i32 = 0;

/// Open MPI's `MPI_MODE_NOCHECK`: the assertion family uses a *dense*
/// 1/2/4/8/16 numbering, deliberately different from MPICH's (and the
/// standard ABI's) 1024..16384 — a §5.4 divergence translation layers
/// must map bit by bit.
pub const MPI_MODE_NOCHECK: i32 = 1;
/// Open MPI's `MPI_MODE_NOPRECEDE`.
pub const MPI_MODE_NOPRECEDE: i32 = 2;
/// Open MPI's `MPI_MODE_NOPUT`.
pub const MPI_MODE_NOPUT: i32 = 4;
/// Open MPI's `MPI_MODE_NOSTORE`.
pub const MPI_MODE_NOSTORE: i32 = 8;
/// Open MPI's `MPI_MODE_NOSUCCEED`.
pub const MPI_MODE_NOSUCCEED: i32 = 16;
/// Open MPI's `MPI_LOCK_EXCLUSIVE` (happens to match the standard ABI).
pub const MPI_LOCK_EXCLUSIVE: i32 = 1;
/// Open MPI's `MPI_LOCK_SHARED`.
pub const MPI_LOCK_SHARED: i32 = 2;

/// Open MPI's `MPI_IN_PLACE` is `(void *) 1`.
pub const fn in_place_ptr() -> *const u8 {
    1 as *const u8
}

// --- Status: Open MPI's layout (§3.2.3) ----------------------------------------

/// Open MPI's `MPI_Status` layout: the three public fields first, then
/// the hidden cancelled flag and `size_t` byte count.
#[repr(C)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[allow(non_snake_case)]
pub struct OmpiStatus {
    /// Public `MPI_SOURCE` field.
    pub MPI_SOURCE: i32,
    /// Public `MPI_TAG` field.
    pub MPI_TAG: i32,
    /// Public `MPI_ERROR` field.
    pub MPI_ERROR: i32,
    /// Hidden cancelled flag.
    pub _cancelled: i32,
    /// Hidden received byte count.
    pub _ucount: usize,
}

// --- Conversion helpers ---------------------------------------------------------

#[inline]
fn deref(p: *const Desc, kind: DescKind) -> Option<&'static Desc> {
    if p.is_null() {
        return None;
    }
    let d = unsafe { &*p };
    if d.magic == DESC_MAGIC && d.kind == kind && d.engine_id != NULL_ID {
        Some(unsafe { std::mem::transmute::<&Desc, &'static Desc>(d) })
    } else {
        None
    }
}

thread_local! {
    /// Handle identity: in Open MPI the handle *is* the object pointer,
    /// so wrapping the same engine object twice must yield the same
    /// address (e.g. `MPI_Comm_get_errhandler` returns what was set).
    static USER_DESCS: std::cell::RefCell<std::collections::HashMap<(u8, u32), *const Desc>> =
        std::cell::RefCell::new(std::collections::HashMap::new());
}

fn alloc(kind: DescKind, engine_id: u32, size: i32) -> *const Desc {
    USER_DESCS.with(|m| {
        *m.borrow_mut().entry((kind as u8, engine_id)).or_insert_with(|| {
            Box::into_raw(Box::new(Desc::new(kind, engine_id, size, false)))
        })
    })
}

fn release(p: *const Desc) {
    if p.is_null() {
        return;
    }
    let d = unsafe { &*p };
    if d.magic == DESC_MAGIC && !d.predefined {
        USER_DESCS.with(|m| m.borrow_mut().remove(&(d.kind as u8, d.engine_id)));
        drop(unsafe { Box::from_raw(p as *mut Desc) });
    }
}

/// The Open-MPI-like representation backend (see the module docs).
pub struct OmpiRepr;

impl Repr for OmpiRepr {
    const NAME: &'static str = "ompi";

    type Comm = OmpiComm;
    type Datatype = OmpiDatatype;
    type Op = OmpiOp;
    type Request = OmpiRequest;
    type Group = OmpiGroup;
    type Errhandler = OmpiErrhandler;
    type Info = OmpiInfo;
    type Win = OmpiWin;
    type Session = OmpiSession;
    type Status = OmpiStatus;

    fn c_comm_world() -> OmpiComm {
        OmpiComm(*COMM_WORLD_DESC)
    }
    fn c_comm_self() -> OmpiComm {
        OmpiComm(*COMM_SELF_DESC)
    }
    fn c_comm_null() -> OmpiComm {
        OmpiComm(*COMM_NULL_DESC)
    }
    fn c_request_null() -> OmpiRequest {
        OmpiRequest(*REQUEST_NULL_DESC)
    }
    fn c_errh_return() -> OmpiErrhandler {
        OmpiErrhandler(*ERRH_RETURN_DESC)
    }
    fn c_errh_fatal() -> OmpiErrhandler {
        OmpiErrhandler(*ERRH_FATAL_DESC)
    }
    fn c_info_null() -> OmpiInfo {
        OmpiInfo(*INFO_NULL_DESC)
    }
    fn c_win_null() -> OmpiWin {
        OmpiWin(*WIN_NULL_DESC)
    }
    fn c_session_null() -> OmpiSession {
        OmpiSession(*SESSION_NULL_DESC)
    }
    fn c_lock_exclusive() -> i32 {
        MPI_LOCK_EXCLUSIVE
    }
    fn c_lock_shared() -> i32 {
        MPI_LOCK_SHARED
    }
    fn c_mode_nocheck() -> i32 {
        MPI_MODE_NOCHECK
    }
    fn c_mode_nostore() -> i32 {
        MPI_MODE_NOSTORE
    }
    fn c_mode_noput() -> i32 {
        MPI_MODE_NOPUT
    }
    fn c_mode_noprecede() -> i32 {
        MPI_MODE_NOPRECEDE
    }
    fn c_mode_nosucceed() -> i32 {
        MPI_MODE_NOSUCCEED
    }

    fn c_datatype(d: Dt) -> OmpiDatatype {
        let id = crate::core::datatype::builtin_id_of_abi(dt_to_abi_const(d)).unwrap();
        OmpiDatatype(DT_DESCS[id.0 as usize])
    }

    fn c_op(o: OpName) -> OmpiOp {
        let id = crate::core::op::builtin_id_of_abi(op_to_abi_const(o)).unwrap();
        OmpiOp(OP_DESCS[id.0 as usize])
    }

    fn c_any_source() -> i32 {
        MPI_ANY_SOURCE
    }
    fn c_any_tag() -> i32 {
        MPI_ANY_TAG
    }
    fn c_proc_null() -> i32 {
        MPI_PROC_NULL
    }
    fn c_undefined() -> i32 {
        MPI_UNDEFINED
    }
    fn c_comm_type_shared() -> i32 {
        MPI_COMM_TYPE_SHARED
    }
    fn c_in_place() -> *const u8 {
        in_place_ptr()
    }

    #[inline]
    fn comm_id(c: OmpiComm) -> RC<CommId> {
        deref(c.0, DescKind::Comm).map(|d| CommId(d.engine_id)).ok_or(err!(MPI_ERR_COMM))
    }

    fn comm_h(id: CommId) -> OmpiComm {
        match id.0 {
            0 => OmpiComm(*COMM_WORLD_DESC),
            1 => OmpiComm(*COMM_SELF_DESC),
            n => OmpiComm(alloc(DescKind::Comm, n, 0)),
        }
    }

    #[inline]
    fn dt_id(d: OmpiDatatype) -> RC<DtId> {
        deref(d.0, DescKind::Datatype).map(|d| DtId(d.engine_id)).ok_or(err!(MPI_ERR_TYPE))
    }

    fn dt_h(id: DtId) -> OmpiDatatype {
        if (id.0 as usize) < DT_DESCS.len() {
            OmpiDatatype(DT_DESCS[id.0 as usize])
        } else {
            // Derived type: cache the engine size in the descriptor, as
            // Open MPI materializes it at type-creation time.
            let size = crate::core::datatype::type_size(id).unwrap_or(0) as i32;
            OmpiDatatype(alloc(DescKind::Datatype, id.0, size))
        }
    }

    #[inline]
    fn op_id(o: OmpiOp) -> RC<OpId> {
        deref(o.0, DescKind::Op).map(|d| OpId(d.engine_id)).ok_or(err!(MPI_ERR_OP))
    }

    fn op_h(id: OpId) -> OmpiOp {
        if id.0 < crate::core::reserved::NUM_BUILTIN_OPS {
            OmpiOp(OP_DESCS[id.0 as usize])
        } else {
            OmpiOp(alloc(DescKind::Op, id.0, 0))
        }
    }

    #[inline]
    fn req_id(r: OmpiRequest) -> RC<ReqId> {
        deref(r.0, DescKind::Request).map(|d| ReqId(d.engine_id)).ok_or(err!(MPI_ERR_REQUEST))
    }

    fn req_h(id: ReqId) -> OmpiRequest {
        OmpiRequest(alloc(DescKind::Request, id.0, 0))
    }

    #[inline]
    fn group_id(g: OmpiGroup) -> RC<GroupId> {
        deref(g.0, DescKind::Group).map(|d| GroupId(d.engine_id)).ok_or(err!(MPI_ERR_GROUP))
    }

    fn group_h(id: GroupId) -> OmpiGroup {
        match id.0 {
            0 => OmpiGroup(*GROUP_EMPTY_DESC),
            n => OmpiGroup(alloc(DescKind::Group, n, 0)),
        }
    }

    #[inline]
    fn errh_id(e: OmpiErrhandler) -> RC<ErrhId> {
        deref(e.0, DescKind::Errhandler).map(|d| ErrhId(d.engine_id)).ok_or(err!(MPI_ERR_ARG))
    }

    fn errh_h(id: ErrhId) -> OmpiErrhandler {
        match id.0 {
            0 => OmpiErrhandler(*ERRH_FATAL_DESC),
            1 => OmpiErrhandler(*ERRH_RETURN_DESC),
            2 => OmpiErrhandler(*ERRH_ABORT_DESC),
            n => OmpiErrhandler(alloc(DescKind::Errhandler, n, 0)),
        }
    }

    #[inline]
    fn info_id(i: OmpiInfo) -> RC<InfoId> {
        deref(i.0, DescKind::Info).map(|d| InfoId(d.engine_id)).ok_or(err!(MPI_ERR_INFO))
    }

    fn info_h(id: InfoId) -> OmpiInfo {
        match id.0 {
            0 => OmpiInfo(*INFO_ENV_DESC),
            n => OmpiInfo(alloc(DescKind::Info, n, 0)),
        }
    }

    #[inline]
    fn win_id(w: OmpiWin) -> RC<WinId> {
        deref(w.0, DescKind::Win).map(|d| WinId(d.engine_id)).ok_or(err!(MPI_ERR_WIN))
    }

    fn win_h(id: WinId) -> OmpiWin {
        OmpiWin(alloc(DescKind::Win, id.0, 0))
    }

    #[inline]
    fn session_id(s: OmpiSession) -> RC<SessionId> {
        deref(s.0, DescKind::Session).map(|d| SessionId(d.engine_id)).ok_or(err!(MPI_ERR_SESSION))
    }

    fn session_h(id: SessionId) -> OmpiSession {
        OmpiSession(alloc(DescKind::Session, id.0, 0))
    }

    fn req_release(r: OmpiRequest) {
        release(r.0);
    }
    fn dt_release(d: OmpiDatatype) {
        release(d.0);
    }
    fn comm_release(c: OmpiComm) {
        release(c.0);
    }
    fn op_release(o: OmpiOp) {
        release(o.0);
    }
    fn group_release(g: OmpiGroup) {
        release(g.0);
    }
    fn errh_release(e: OmpiErrhandler) {
        release(e.0);
    }
    fn info_release(i: OmpiInfo) {
        release(i.0);
    }
    fn win_release(w: OmpiWin) {
        release(w.0);
    }
    fn session_release(s: OmpiSession) {
        release(s.0);
    }

    fn status_empty() -> OmpiStatus {
        OmpiStatus {
            MPI_SOURCE: MPI_PROC_NULL,
            MPI_TAG: MPI_ANY_TAG,
            MPI_ERROR: 0,
            _cancelled: 0,
            _ucount: 0,
        }
    }

    fn status_from_core(s: &StatusCore) -> OmpiStatus {
        OmpiStatus {
            MPI_SOURCE: s.source,
            MPI_TAG: s.tag,
            MPI_ERROR: s.error,
            _cancelled: s.cancelled as i32,
            _ucount: s.count_bytes as usize,
        }
    }

    fn status_source(s: &OmpiStatus) -> i32 {
        s.MPI_SOURCE
    }
    fn status_tag(s: &OmpiStatus) -> i32 {
        s.MPI_TAG
    }
    fn status_error(s: &OmpiStatus) -> i32 {
        s.MPI_ERROR
    }
    fn status_cancelled(s: &OmpiStatus) -> bool {
        s._cancelled != 0
    }
    fn status_count_bytes(s: &OmpiStatus) -> u64 {
        s._ucount as u64
    }

    /// Open MPI returns canonical classes directly as codes.
    fn err_from_class(class: i32) -> i32 {
        class
    }
    fn class_of_err(code: i32) -> i32 {
        code
    }

    /// Open MPI's mechanism: dereference the (352-byte) descriptor.
    #[inline(always)]
    fn type_size_fast(d: OmpiDatatype) -> Option<i32> {
        deref(d.0, DescKind::Datatype).map(|desc| desc.size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_is_352_bytes() {
        assert_eq!(core::mem::size_of::<Desc>(), 352);
    }

    #[test]
    fn constants_are_addresses_not_literals() {
        // Two reads of the "constant" give the same address (link-time
        // semantics), and it's a real dereferenceable descriptor.
        let a = OmpiRepr::c_comm_world();
        let b = OmpiRepr::c_comm_world();
        assert_eq!(a, b);
        assert_eq!(OmpiRepr::comm_id(a).unwrap(), crate::core::reserved::COMM_WORLD);
    }

    #[test]
    fn null_handles_fail_conversion() {
        let n = OmpiRepr::c_comm_null();
        assert!(OmpiRepr::comm_id(n).is_err());
        let rn = OmpiRepr::c_request_null();
        assert!(OmpiRepr::req_id(rn).is_err());
    }

    #[test]
    fn wrong_kind_pointer_rejected() {
        // A datatype descriptor passed as a comm must be rejected (this is
        // what incomplete-struct-pointer typing prevents in C at compile
        // time; at runtime the magic/kind check catches casts).
        let dt = OmpiRepr::c_datatype(crate::api::Dt::Int);
        let fake = OmpiComm(dt.0);
        assert!(OmpiRepr::comm_id(fake).is_err());
    }

    #[test]
    fn dtype_size_via_descriptor() {
        assert_eq!(OmpiRepr::type_size_fast(OmpiRepr::c_datatype(crate::api::Dt::Int)), Some(4));
        assert_eq!(
            OmpiRepr::type_size_fast(OmpiRepr::c_datatype(crate::api::Dt::Double)),
            Some(8)
        );
    }

    #[test]
    fn status_layout_matches_ompi() {
        let s = OmpiStatus { MPI_SOURCE: 1, MPI_TAG: 2, MPI_ERROR: 3, _cancelled: 0, _ucount: 9 };
        let base = &s as *const _ as usize;
        assert_eq!(&s.MPI_SOURCE as *const _ as usize - base, 0);
        assert_eq!(&s._ucount as *const _ as usize - base, 16);
        assert_eq!(core::mem::size_of::<OmpiStatus>(), 24);
    }

    #[test]
    fn proc_null_and_any_source_use_ompi_numbering() {
        assert_eq!(MPI_ANY_SOURCE, -1);
        assert_eq!(MPI_PROC_NULL, -2);
        // Different from both MPICH and the standard ABI:
        assert_ne!(MPI_ANY_SOURCE, crate::impls::mpich::MPI_ANY_SOURCE);
        assert_ne!(MPI_ANY_SOURCE, crate::abi::constants::MPI_ANY_SOURCE);
    }
}
