//! Acceptance: the RMA halo exchange produces bitwise-identical results
//! to the pt2pt (sendrecv) and persistent modes, under every ABI
//! configuration and on both transports.

use mpi_abi::api::MpiAbi;
use mpi_abi::apps::halo::{jacobi, HaloMode, HaloParams};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::core::transport::TransportKind;
use mpi_abi::launcher::{run_job_ok, JobSpec};

const RANKS: usize = 3;
const N: usize = 48;
const ITERS: usize = 8;

struct Halo {
    transport: TransportKind,
    mode: HaloMode,
}

impl AbiApp<f64> for Halo {
    fn run<A: MpiAbi>(self) -> f64 {
        let mode = self.mode;
        let out = run_job_ok(JobSpec::new(RANKS).with_transport(self.transport), move |_| {
            A::init();
            let (_, global) = jacobi::<A>(HaloParams { n: N, iters: ITERS, mode });
            A::finalize();
            global
        });
        out[0]
    }
}

#[test]
fn rma_halo_bitwise_matches_pt2pt_all_configs_both_transports() {
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        // Reference: sendrecv on the native standard ABI.
        let reference = with_abi(
            AbiConfig::NativeAbi,
            Halo { transport, mode: HaloMode::Sendrecv },
        );
        assert!(reference > 0.0, "heat must have diffused");
        for abi in AbiConfig::ALL {
            for mode in [HaloMode::Sendrecv, HaloMode::Persistent, HaloMode::Rma] {
                let got = with_abi(abi, Halo { transport, mode });
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "{} / {} on {} transport diverged: {got} vs {reference}",
                    abi.name(),
                    mode.name(),
                    transport.name(),
                );
            }
        }
    }
}
