//! Nonblocking-collective ablations: Ibcast / Iallreduce latency through
//! every ABI layer (native mpich/ompi, Mukautuva over both, native
//! standard ABI), on both transports, plus a communication/computation
//! overlap ratio — the request-heaviest paths a translation layer pays
//! for (§6.2), now measured end to end.
//!
//! Per-layer translation overhead is reported relative to the mpich
//! baseline on the same transport, so the schedule engine's cost cancels
//! out and only representation/translation remains.

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::bench::Table;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::launcher::{run_job_ok, JobSpec};

const RANKS: usize = 2;
const COUNT: usize = 4096; // f32 elements per operation

/// Busy compute kernel used to probe overlap (pure FLOPs, no MPI).
fn compute_kernel(work: &mut [f32]) {
    for x in work.iter_mut() {
        let mut v = *x;
        for _ in 0..8 {
            v = v.mul_add(1.0000001, 0.0000001);
        }
        *x = v;
    }
    std::hint::black_box(work);
}

struct Results {
    ibcast_us: f64,
    iallreduce_us: f64,
    overlap_ratio: f64,
}

struct NbColl {
    transport: TransportKind,
    iters: usize,
}

impl AbiApp<Results> for NbColl {
    fn run<A: MpiAbi>(self) -> Results {
        let iters = self.iters;
        let out = run_job_ok(JobSpec::new(RANKS).with_transport(self.transport), move |_| {
            A::init();
            let dt = A::datatype(Dt::Float);
            let op = A::op(OpName::Sum);
            // Per-rank buffers: the job closure runs once on every rank
            // thread, so allocation must happen inside it.
            let send = vec![1.0f32; COUNT];
            let mut recv = vec![0.0f32; COUNT];
            let mut bc = vec![2.0f32; COUNT];
            let mut work = vec![1.0f32; COUNT];

            // Warmup (primes vtables, schedules, rings).
            for _ in 0..5 {
                let mut req = A::request_null();
                A::ibcast(bc.as_mut_ptr() as *mut u8, COUNT as i32, dt, 0, A::comm_world(),
                    &mut req);
                let mut st = A::status_empty();
                A::wait(&mut req, &mut st);
            }

            // (a) Ibcast latency: issue + wait.
            let t0 = A::wtime();
            for _ in 0..iters {
                let mut req = A::request_null();
                A::ibcast(bc.as_mut_ptr() as *mut u8, COUNT as i32, dt, 0, A::comm_world(),
                    &mut req);
                let mut st = A::status_empty();
                A::wait(&mut req, &mut st);
            }
            let t_ibcast = (A::wtime() - t0) / iters as f64;

            // (b) Iallreduce latency.
            let t0 = A::wtime();
            for _ in 0..iters {
                let mut req = A::request_null();
                A::iallreduce(send.as_ptr() as *const u8, recv.as_mut_ptr() as *mut u8,
                    COUNT as i32, dt, op, A::comm_world(), &mut req);
                let mut st = A::status_empty();
                A::wait(&mut req, &mut st);
            }
            let t_iallreduce = (A::wtime() - t0) / iters as f64;

            // (c) Overlap: blocking collective time, compute-alone time,
            // then icoll → compute → wait. Saved time over the serial sum,
            // normalized by the collective cost.
            let t0 = A::wtime();
            for _ in 0..iters {
                A::allreduce(send.as_ptr() as *const u8, recv.as_mut_ptr() as *mut u8,
                    COUNT as i32, dt, op, A::comm_world());
            }
            let t_coll = (A::wtime() - t0) / iters as f64;

            let t0 = A::wtime();
            for _ in 0..iters {
                compute_kernel(&mut work);
            }
            let t_comp = (A::wtime() - t0) / iters as f64;

            let t0 = A::wtime();
            for _ in 0..iters {
                let mut req = A::request_null();
                A::iallreduce(send.as_ptr() as *const u8, recv.as_mut_ptr() as *mut u8,
                    COUNT as i32, dt, op, A::comm_world(), &mut req);
                compute_kernel(&mut work);
                let mut st = A::status_empty();
                A::wait(&mut req, &mut st);
            }
            let t_ovl = (A::wtime() - t0) / iters as f64;

            let saved = (t_coll + t_comp - t_ovl).max(0.0);
            let overlap = if t_coll > 0.0 { (saved / t_coll).min(1.0) } else { 0.0 };

            A::finalize();
            Results {
                ibcast_us: t_ibcast * 1e6,
                iallreduce_us: t_iallreduce * 1e6,
                overlap_ratio: overlap,
            }
        });
        // Aggregate across ranks with max: the ibcast *root*'s request
        // completes at issue time (its schedule is all eager sends), so
        // rank 0 alone would report pack+enqueue cost, not broadcast
        // latency. The slowest rank is the operation's latency.
        out.into_iter()
            .reduce(|a, b| Results {
                ibcast_us: a.ibcast_us.max(b.ibcast_us),
                iallreduce_us: a.iallreduce_us.max(b.iallreduce_us),
                overlap_ratio: a.overlap_ratio.max(b.overlap_ratio),
            })
            .unwrap()
    }
}

fn main() {
    println!("\nNonblocking collectives ({RANKS} ranks, {COUNT} f32): latency + overlap");
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        let iters = match transport {
            TransportKind::Spsc => 300,
            TransportKind::Mutex => 100,
        };
        let mut rows: Vec<(AbiConfig, Results)> = Vec::new();
        for abi in AbiConfig::ALL {
            let r = with_abi(abi, NbColl { transport, iters });
            rows.push((abi, r));
        }
        // Per-layer translation overhead vs the mpich baseline.
        let base = rows
            .iter()
            .find(|(a, _)| *a == AbiConfig::Mpich)
            .map(|(_, r)| r.iallreduce_us)
            .unwrap_or(f64::NAN);
        let mut table = Table::new(
            &format!("nonblocking collectives [{} transport]", transport.name()),
            &["ABI", "ibcast µs", "iallreduce µs", "vs mpich", "overlap"],
        );
        for (abi, r) in &rows {
            table.row(&[
                abi.name().to_string(),
                format!("{:.1}", r.ibcast_us),
                format!("{:.1}", r.iallreduce_us),
                format!("{:+.1}%", (r.iallreduce_us / base - 1.0) * 100.0),
                format!("{:.2}", r.overlap_ratio),
            ]);
        }
        println!("{}", table.render());
    }
    println!(
        "shape: translation layers (muk rows) add only handle/request conversion — single-digit \
         percent at this message size, matching Table 1's \"trivial overhead\" claim; the native \
         standard ABI tracks mpich within noise."
    );
}
