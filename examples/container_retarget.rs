//! The §4.7 container story, executable: **one application "binary",
//! retargeted across MPI implementations without recompilation.**
//!
//! The application below is compiled exactly once against the standard
//! ABI (it is a single monomorphic function over standard-ABI types).
//! At "launch time" we run the same function against three different
//! libraries: Mukautuva→MPICH-like, Mukautuva→OpenMPI-like, and the
//! native standard-ABI implementation — the drop-in replacement an ABI
//! makes possible, where today a container image would need one build
//! per vendor MPI.
//!
//! ```bash
//! cargo run --release --example container_retarget
//! ```

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;

/// "The container's entrypoint" — note: NOT generic. It is written
/// against the standard-ABI types only; the three backends below all
/// satisfy the same binary contract.
fn containerized_app<A>(_rank: usize) -> (i32, f64, String)
where
    // The one compile-time fact the app relies on: its MPI speaks the
    // standard ABI types (AbiComm-sized handles, 32-byte status, …).
    A: MpiAbi<
        Comm = mpi_abi::abi::handles::AbiComm,
        Datatype = mpi_abi::abi::handles::AbiDatatype,
        Op = mpi_abi::abi::handles::AbiOp,
        Status = mpi_abi::abi::status::AbiStatus,
    >,
{
    A::init();
    let world = A::comm_world();
    let (mut size, mut rank) = (0, 0);
    A::comm_size(world, &mut size);
    A::comm_rank(world, &mut rank);

    // A small halo-ish workload: neighbor exchange + global reduction.
    let dt = A::datatype(Dt::Double);
    let right = (rank + 1) % size;
    let left = (rank + size - 1) % size;
    let send = [f64::from(rank) * 1.5];
    let mut recv = [0.0f64];
    let mut st = A::status_empty();
    A::sendrecv(
        send.as_ptr() as *const u8,
        1,
        dt,
        right,
        0,
        recv.as_mut_ptr() as *mut u8,
        1,
        dt,
        left,
        0,
        world,
        &mut st,
    );
    let mut sum = [0.0f64];
    let local = [recv[0]];
    A::allreduce(
        local.as_ptr() as *const u8,
        sum.as_mut_ptr() as *mut u8,
        1,
        dt,
        A::op(OpName::Sum),
        world,
    );
    let lib = A::get_library_version();
    A::finalize();
    (rank, sum[0], lib)
}

fn main() {
    println!("same application, three MPI libraries, zero recompilation:\n");
    let n = 3;

    // "docker run --mpi=host-mpich app"
    let out = run_job_ok(JobSpec::new(n), containerized_app::<MukMpich>);
    report("muk → mpich-like host MPI", &out);

    // "docker run --mpi=host-ompi app"
    let out = run_job_ok(JobSpec::new(n), containerized_app::<MukOmpi>);
    report("muk → ompi-like host MPI", &out);

    // "docker run --mpi=native-abi app"
    let out = run_job_ok(JobSpec::new(n), containerized_app::<NativeAbi>);
    report("native standard-ABI MPI", &out);

    println!("\nall three runs computed identical results from one \"binary\" —");
    println!("the retargeting §4.7 says an ABI standard makes possible.");
}

fn report(label: &str, out: &[(i32, f64, String)]) {
    let expect: f64 = out.iter().map(|(r, _, _)| f64::from(*r) * 1.5).sum();
    for (rank, sum, lib) in out {
        assert_eq!(*sum, expect, "wrong reduction under {label}");
        if *rank == 0 {
            println!("[{label}]");
            println!("   library: {lib}");
            println!("   global sum: {sum} (expected {expect})");
        }
    }
}
